"""Fault-tolerance tier-1 shard (ISSUE 9): crash-safe ingest, elastic
resize, torn checkpoints, degraded-mode serving.

Pinned recovery contracts (bitwise where the contract is bitwise):

  * kill the ingest worker mid-round -> WAL replay onto a fresh service
    -> ``finalize()`` BITWISE the uninterrupted run;
  * ``reshard_stream`` across 8 -> 4 and 4 -> 8 grids mid-stream ->
    bitwise finalize (8 fake devices, subprocess);
  * the reshard hop's measured ledger bytes equal the
    ``plan.model.stream_reshard_traffic_words`` prediction exactly
    (drift = 0) on the pinned grid pairs;
  * a torn checkpoint is NEVER restored: ``latest_step`` skips it,
    explicit ``restore(step=...)`` raises TornCheckpointError, and
    ``quarantine_torn`` renames it out of the step sequence;
  * ``elastic_restore`` 8 -> 4 fake devices + ``rescale_accum`` (the
    round trip launch/elastic.py's docstring advertises);
  * poison-lane excision: when a round's retries exhaust, only the
    poison lane is quarantined — its cohort's tenants still land;
  * transient-round retry with backoff under a deadline;
  * ``WorkerDied`` fast-fail on submit/flush/close_stream after a worker
    crash (never hang on a queue nobody drains); idempotent shutdown.
  * distributed rounds are exactly-once per lane: a per-lane dispatch
    that fails partway through never re-applies its landed prefix on
    retry or in the poison-excision fallback;
  * WAL replay onto a distributed service applies records additively
    (full-shape, no row offset) and refuses local-mode row slabs;
    replaying a reopened WriteAheadLog advances its applied watermark so
    a reattached queue can resolve and truncate the recovered prefix.
"""
import os
import threading
import time

import numpy as np
import pytest

from dist_helper import run_distributed

from repro.checkpoint import ckpt
from repro.stream import faults
from repro.stream import wal as wal_mod
from repro.stream.ingest import IngestQueue, WorkerDied
from repro.stream.service import SketchService
from repro.stream.state import StreamConfig


@pytest.fixture(autouse=True)
def _clean_faults():
    """The chaos registry is process-global: guarantee every test starts
    and ends with nothing armed."""
    faults.clear()
    yield
    faults.clear()


def _mk_traffic(rng, streams, updates, n1, n2):
    """updates-per-stream row-block traffic, per-stream FIFO order."""
    traffic = []
    for _ in range(updates):
        for s in range(streams):
            k = int(rng.integers(1, 17))
            traffic.append((s, rng.standard_normal((k, n2)).astype("float32"),
                            int(rng.integers(0, n1 - k + 1))))
    return traffic


def _reference(cfgs, traffic):
    """The run that never crashes: same traffic, same per-stream order."""
    ref = SketchService()
    sids = [ref.open(c) for c in cfgs]
    for s, H, row0 in traffic:
        ref.update(sids[s], H, row0=row0)
    return [np.asarray(ref.sketch(s)) for s in sids]


# ---------------------------------------------------------------------------
# write-ahead log
# ---------------------------------------------------------------------------


def test_wal_append_scan_roundtrip(tmp_path):
    path = str(tmp_path / "ingest.wal")
    rng = np.random.default_rng(0)
    payloads = [(s, int(rng.integers(0, 8)),
                 rng.standard_normal((1 + s, 6)).astype("float32"))
                for s in range(5)]
    with wal_mod.WriteAheadLog(path) as wal:
        seqs = [wal.append(sid, row0, H) for sid, row0, H in payloads]
        assert seqs == [1, 2, 3, 4, 5]
        assert wal.depth == 5

        records, torn = wal_mod.scan(path)
        assert torn is None
        for rec, (sid, row0, H) in zip(records, payloads):
            assert (rec.sid, rec.row0) == (sid, row0)
            assert rec.words == H.size
            np.testing.assert_array_equal(rec.H, H)   # bitwise payload

        # watermark advance + truncate drop the applied prefix atomically
        wal.mark_applied(3)
        assert wal.watermark == 3 and wal.depth == 2
        assert wal.truncate() == 2
        assert [r.seqno for r in wal.pending()] == [4, 5]

    # reopen resumes the seqno sequence past what is durable
    with wal_mod.WriteAheadLog(path) as wal2:
        assert wal2.append(9, 0, payloads[0][2]) == 6


def test_wal_torn_tail_discarded(tmp_path):
    path = str(tmp_path / "ingest.wal")
    H = np.arange(12, dtype=np.float32).reshape(3, 4)
    with wal_mod.WriteAheadLog(path) as wal:
        for _ in range(3):
            wal.append(1, 0, H)
    # crash mid-append: cut into the last record's payload/CRC
    size = os.path.getsize(path)
    with open(path, "rb+") as f:
        f.truncate(size - 7)
    records, torn = wal_mod.scan(path)
    assert len(records) == 2 and torn is not None
    assert "truncated" in torn.reason
    # reopening repairs the file to its intact prefix and resumes seqnos
    with wal_mod.WriteAheadLog(path) as wal2:
        assert wal2.append(1, 0, H) == 3
    records, torn = wal_mod.scan(path)
    assert torn is None and [r.seqno for r in records] == [1, 2, 3]


def test_wal_bad_magic_is_torn(tmp_path):
    path = str(tmp_path / "ingest.wal")
    with open(path, "wb") as f:
        f.write(b"NOTAWALRECORD???")
    records, torn = wal_mod.scan(path)
    assert records == [] and torn.reason == "bad magic" and torn.offset == 0


def test_kill_worker_mid_round_wal_replay_bitwise(tmp_path):
    """Acceptance (a): crash the worker mid-round, replay the journal into
    a fresh service — finalize is bitwise the uninterrupted run."""
    rng = np.random.default_rng(1)
    n1, n2, r, streams, updates = 64, 32, 4, 4, 3
    cfgs = [StreamConfig(n1=n1, n2=n2, r=r, seed=s, corange=False)
            for s in range(streams)]
    traffic = _mk_traffic(rng, streams, updates, n1, n2)
    ref_Y = _reference(cfgs, traffic)

    svc = SketchService()
    sids = [svc.open(c) for c in cfgs]
    wal = wal_mod.WriteAheadLog(str(tmp_path / "ingest.wal"))
    q = IngestQueue(svc, wal=wal)
    # every submit of one sid lands in a distinct round, so >= `updates`
    # rounds run — round index updates-1 is mid-stream and guaranteed
    faults.arm("ingest.apply_round", exc=faults.WorkerKilled, times=None,
               match={"round_index": max(2, updates - 1)})
    died = False
    for s, H, row0 in traffic:
        try:
            q.submit(sids[s], H, row0)
        except WorkerDied:
            died = True
            break
    if not died:
        with pytest.raises(WorkerDied):
            q.flush()
        died = True
    faults.disarm("ingest.apply_round")
    assert died and wal.depth > 0     # journaled-but-unapplied tail exists
    q.shutdown()
    q.shutdown()                      # idempotent on a corpse
    wal.close()

    svc2 = SketchService()
    sids2 = [svc2.open(c) for c in cfgs]
    nrec, words = wal_mod.replay(wal.path, svc2,
                                 sid_map=dict(zip(sids, sids2)))
    assert nrec == len(traffic) and words == sum(H.size
                                                 for _, H, _ in traffic)
    for s2, ref in zip(sids2, ref_Y):
        np.testing.assert_array_equal(np.asarray(svc2.sketch(s2)), ref)


def test_wal_replay_respects_watermark(tmp_path):
    """Checkpoint + journal-tail recovery: records at or below the
    restored watermark are skipped, the tail replays bitwise."""
    rng = np.random.default_rng(2)
    cfg = StreamConfig(n1=64, n2=32, r=4, seed=7, corange=False)
    traffic = _mk_traffic(rng, 1, 4, cfg.n1, cfg.n2)
    ref_Y = _reference([cfg], traffic)[0]

    wal = wal_mod.WriteAheadLog(str(tmp_path / "ingest.wal"))
    for _, H, row0 in traffic:
        wal.append(0, row0, H)
    wal.close()

    svc = SketchService()
    sid = svc.open(cfg)
    for _, H, row0 in traffic[:2]:    # "restored from a step-2 checkpoint"
        svc.update(sid, H, row0=row0)
    nrec, _ = wal_mod.replay(wal.path, svc, sid_map={0: sid}, watermark=2)
    assert nrec == len(traffic) - 2
    np.testing.assert_array_equal(np.asarray(svc.sketch(sid)), ref_Y)


# ---------------------------------------------------------------------------
# torn checkpoints
# ---------------------------------------------------------------------------


def test_torn_checkpoint_quarantined_never_restored(tmp_path):
    """Acceptance (c): a torn step is skipped by latest_step, refused by
    explicit restore, and renamed out of the sequence by quarantine."""
    d = str(tmp_path / "ckpt")
    state1 = {"w": np.arange(12, dtype=np.float32).reshape(3, 4)}
    state2 = {"w": state1["w"] + 1.0}
    ckpt.save(d, 1, state1)

    def tear(tmp, **_):
        os.remove(os.path.join(tmp, "manifest.json"))

    faults.arm("ckpt.pre_commit", handler=tear, match={"step": 2})
    ckpt.save(d, 2, state2)           # publishes a torn step_00000002
    faults.disarm("ckpt.pre_commit")

    assert ckpt.torn_steps(d) == [2]
    assert ckpt.latest_step(d) == 1   # torn step skipped, not loaded
    tree, step, _ = ckpt.restore(d, state1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(tree["w"]), state1["w"])
    with pytest.raises(ckpt.TornCheckpointError):
        ckpt.restore(d, state2, step=2)
    assert ckpt.quarantine_torn(d) == [2]
    assert ckpt.torn_steps(d) == []
    assert os.path.isdir(os.path.join(d, "step_00000002.torn"))


def test_ckpt_crash_before_commit_leaves_no_step(tmp_path):
    """Atomicity: a crash before the os.replace publishes NOTHING — no
    step dir, no tmp leftover visible as a step."""
    d = str(tmp_path / "ckpt")
    state = {"w": np.zeros(3, np.float32)}
    ckpt.save(d, 1, state)
    faults.arm("ckpt.pre_commit", exc=faults.FaultInjected,
               match={"step": 2})
    with pytest.raises(faults.FaultInjected):
        ckpt.save(d, 2, state)
    faults.disarm("ckpt.pre_commit")
    assert ckpt.latest_step(d) == 1
    assert ckpt.torn_steps(d) == []
    assert not os.path.exists(os.path.join(d, "step_00000002"))


# ---------------------------------------------------------------------------
# live mesh resize (8 fake devices, subprocess)
# ---------------------------------------------------------------------------


def test_reshard_stream_8_4_8_bitwise_finalize():
    """Acceptance (b): shrink 8 -> 4 mid-stream, grow 4 -> 8, keep
    updating — finalize is bitwise the never-resized run."""
    run_distributed(r"""
import numpy as np, jax
from repro.core.sketch import make_grid_mesh
from repro.stream import ShardedStreamingSketch, StreamConfig
from repro.stream.elastic import reshard_stream

cfg = StreamConfig(n1=256, n2=128, r=8, seed=11, corange=False)
rng = np.random.default_rng(0)
slabs = [(i * 64, rng.standard_normal((64, 128)).astype("float32"))
         for i in range(4)]

ref = ShardedStreamingSketch(cfg, make_grid_mesh(8, 1, 1), backend="jnp")
for row0, H in slabs:
    ref.update_rows(row0, H)

sk = ShardedStreamingSketch(cfg, make_grid_mesh(8, 1, 1), backend="jnp")
for row0, H in slabs[:2]:
    sk.update_rows(row0, H)
sk = reshard_stream(sk, (4, 1, 1))      # device loss: 8 -> 4
assert tuple(int(sk.mesh.shape[a]) for a in sk.axes) == (4, 1, 1)
sk.update_rows(*slabs[2])               # keep streaming on the small grid
sk = reshard_stream(sk, (8, 1, 1))      # devices came back: 4 -> 8
sk.update_rows(*slabs[3])
assert sk.num_updates == ref.num_updates
np.testing.assert_array_equal(np.asarray(jax.device_get(sk.Y)),
                              np.asarray(jax.device_get(ref.Y)))
print("OK")
""")


def test_reshard_ledger_drift_is_zero():
    """Acceptance (d): the reshard hop's measured HLO bytes equal the
    planner's stream_reshard_traffic_words prediction EXACTLY on the
    pinned pairs — a relayout that moves full new shards, a
    coinciding-layout relabel that moves nothing, and a both-axes
    re-split ((4,1,2) -> (2,1,4): the column axis re-splits while
    already split) that pays TWO full-shard hops (all-to-all +
    collective-permute) — the pair the old model underpriced 2x."""
    run_distributed(r"""
import numpy as np
from repro.core.sketch import make_grid_mesh
from repro.obs import install_ledger
from repro.plan import model as M
from repro.stream import ShardedStreamingSketch, StreamConfig
from repro.stream.elastic import LEDGER_SITE, reshard_stream

cfg = StreamConfig(n1=256, n2=128, r=8, seed=0, corange=False)
rng = np.random.default_rng(0)
H = rng.standard_normal((64, 128)).astype("float32")
# (8,1,1)->(2,2,2): layouts differ -> one full NEW shard per device;
# (8,1,1)->(4,2,1): Y's layout coincides device-for-device -> zero words;
# (4,1,2)->(2,1,4): both Y axes re-split with p3>1 either side -> 2x shard
for old_grid, new_grid, want_pred, want_floor in (
        ((8, 1, 1), (2, 2, 2), 256.0, 128.0),
        ((8, 1, 1), (4, 2, 1), 0.0, 0.0),
        ((4, 1, 2), (2, 1, 4), 512.0, 256.0)):
    led = install_ledger()
    sk = ShardedStreamingSketch(cfg, make_grid_mesh(*old_grid),
                                backend="jnp")
    sk.update_rows(0, H)
    reshard_stream(sk, new_grid)
    pred = M.stream_reshard_traffic_words(cfg.n1, cfg.r, old_grid,
                                          new_grid)
    floor = M.stream_reshard_words(cfg.n1, cfg.r, old_grid, new_grid)
    assert (pred, floor) == (want_pred, want_floor), (pred, floor)
    site = led.site(LEDGER_SITE)
    assert site is not None and site.calls == 1
    assert site.predicted_words == pred
    assert site.lower_bound_words == floor
    assert site.measured_words_per_call == pred, (
        old_grid, new_grid, site.measured_words_per_call, pred)
    assert site.drift == 0.0, (old_grid, new_grid, site.drift)
    print("DRIFT_OK", old_grid, new_grid, site.measured_words_per_call)
print("OK")
""")


def test_service_reshard_and_drain_resume():
    """The degraded-mode arc through the queue: drain -> reshard every
    resident stream -> resume ingest, bitwise against an undisturbed
    distributed service.  (1,1,1) -> (1,1,1) runs the full production
    path — drain, per-stream hop, executable-cache drop, resume — on the
    single-device pytest process."""
    from repro.core.sketch import make_grid_mesh
    from repro.stream.elastic import drain_reshard_resume

    rng = np.random.default_rng(3)
    cfgs = [StreamConfig(n1=32, n2=16, r=4, seed=s, corange=False)
            for s in range(2)]
    traffic = [(s, rng.standard_normal((32, 16)).astype("float32"))
               for _ in range(3) for s in range(2)]

    ref = SketchService(mesh=make_grid_mesh(1, 1, 1))
    ref_sids = [ref.open(c) for c in cfgs]
    for s, H in traffic:
        ref.update(ref_sids[s], H)

    svc = SketchService(mesh=make_grid_mesh(1, 1, 1))
    sids = [svc.open(c) for c in cfgs]
    with IngestQueue(svc) as q:
        for s, H in traffic[:2]:
            q.submit(sids[s], H)
        out = drain_reshard_resume(q, (1, 1, 1))
        assert out == {"drained": 2, "resharded": 2}
        for s, H in traffic[2:]:      # resume: rounds recompile, then land
            q.submit(sids[s], H)
        q.flush(raise_errors=True)
    for sid, ref_sid in zip(sids, ref_sids):
        np.testing.assert_array_equal(np.asarray(svc.sketch(sid)),
                                      np.asarray(ref.sketch(ref_sid)))


def test_elastic_restore_8_to_4_round_trip():
    """The round trip launch/elastic.py's docstring advertises: restore
    one checkpoint onto 8 then 4 fake devices (params bitwise equal), and
    rescale gradient accumulation so the global batch is preserved."""
    run_distributed(r"""
import jax
import numpy as np
import tempfile
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.models import get_api
from repro.train.step import init_state
from repro.checkpoint import ckpt
from repro.launch.elastic import elastic_restore, remesh, rescale_accum

cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=64, d_ff=128,
                                      vocab=128, head_dim=16)
api = get_api(cfg)
state = init_state(api, cfg, RunConfig(steps=10), jax.random.key(0))
d = tempfile.mkdtemp()
ckpt.save(d, 7, state)

mesh8 = remesh(jax.devices(), dp=4, tp=2)
st8, step8, _ = elastic_restore(d, state, mesh=mesh8)
mesh4 = remesh(jax.devices()[:4], dp=2, tp=2)   # half the devices died
st4, step4, _ = elastic_restore(d, state, mesh=mesh4)
assert step8 == step4 == 7
for a, b in zip(jax.tree_util.tree_leaves(st8.params),
                jax.tree_util.tree_leaves(st4.params)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

accum8, gb8 = rescale_accum(global_batch=128, per_device_batch=4, dp_size=4)
accum4, gb4 = rescale_accum(global_batch=128, per_device_batch=4, dp_size=2)
assert gb8 == gb4 == 128 and accum4 == 2 * accum8
print("OK")
""")


# ---------------------------------------------------------------------------
# degraded-mode ingest: retry, backoff, poison excision, fast-fail
# ---------------------------------------------------------------------------


def test_transient_round_failure_retried_then_lands():
    rng = np.random.default_rng(4)
    cfgs = [StreamConfig(n1=32, n2=16, r=4, seed=s, corange=False)
            for s in range(2)]
    traffic = _mk_traffic(rng, 2, 2, 32, 16)
    ref_Y = _reference(cfgs, traffic)

    svc = SketchService()
    sids = [svc.open(c) for c in cfgs]
    faults.arm("ingest.apply_round", exc=faults.FaultInjected, times=1)
    with IngestQueue(svc, max_retries=2, backoff_base=0.0) as q:
        for s, H, row0 in traffic:
            q.submit(sids[s], H, row0)
        q.flush(raise_errors=True)    # the retry absorbed the fault
        st = q.stats()
    assert st["retries"] >= 1 and st["errors"] == 0
    assert st["quarantined"] == 0 and st["applied"] == len(traffic)
    assert faults.fire_count("ingest.apply_round") == 1
    for sid, ref in zip(sids, ref_Y):
        np.testing.assert_array_equal(np.asarray(svc.sketch(sid)), ref)


def test_retry_deadline_forfeits_remaining_retries():
    svc = SketchService()
    sid = svc.open(StreamConfig(n1=32, n2=16, r=4, seed=0, corange=False))
    H = np.ones((4, 16), np.float32)
    # the round ALWAYS fails; with a 10ms budget and 0.2s backoff the
    # worker must give up after one retry and fall back per-lane (the
    # lane itself is healthy, so the update still lands)
    faults.arm("ingest.apply_round", exc=faults.FaultInjected, times=None)
    with IngestQueue(svc, max_retries=5, backoff_base=0.2,
                     retry_deadline=0.01) as q:
        q.submit(sid, H, 0)
        q.flush(raise_errors=True)
        st = q.stats()
    assert st["applied"] == 1 and st["errors"] == 0
    assert st["retries"] < 5          # deadline forfeited the rest


def test_poison_lane_excised_cohort_survives():
    rng = np.random.default_rng(5)
    cfgs = [StreamConfig(n1=32, n2=16, r=4, seed=s, corange=False)
            for s in range(3)]
    traffic = _mk_traffic(rng, 3, 2, 32, 16)
    ref_Y = _reference(cfgs, traffic)

    svc = SketchService()
    sids = [svc.open(c) for c in cfgs]
    bad = sids[1]
    # every fused round fails -> per-lane fallback; exactly one tenant is
    # poison, the others must land every time
    faults.arm("ingest.apply_round", exc=faults.FaultInjected, times=None)
    faults.arm("ingest.apply_lane", exc=faults.FaultInjected, times=None,
               match={"sid": bad})
    with IngestQueue(svc, max_retries=0, backoff_base=0.0) as q:
        for s, H, row0 in traffic:
            q.submit(sids[s], H, row0)
        applied = q.flush()
        st = q.stats()
        with pytest.raises(RuntimeError, match=r"ingest failure"):
            q.flush(raise_errors=True)
    assert applied == 4 and st["quarantined"] == 2 and st["errors"] == 2
    # healthy tenants: bitwise identical to the undisturbed run
    for sid, ref in zip(sids, ref_Y):
        if sid != bad:
            np.testing.assert_array_equal(np.asarray(svc.sketch(sid)), ref)
    # the poison lane was excised BEFORE it could touch its accumulators
    fresh = SketchService()
    fsid = fresh.open(cfgs[1])
    np.testing.assert_array_equal(np.asarray(svc.sketch(bad)),
                                  np.asarray(fresh.sketch(fsid)))


def test_distributed_partial_round_retry_exactly_once():
    """A distributed round applies lanes sequentially; when lane k fails
    mid-round, the retry must re-run ONLY the not-yet-applied suffix —
    the landed prefix must not double-apply into (Y, W)."""
    from repro.core.sketch import make_grid_mesh

    rng = np.random.default_rng(6)
    cfgs = [StreamConfig(n1=32, n2=16, r=4, seed=s, corange=False)
            for s in range(3)]
    deltas = [rng.standard_normal((32, 16)).astype("float32")
              for _ in range(3)]

    ref = SketchService(mesh=make_grid_mesh(1, 1, 1))
    ref_sids = [ref.open(c) for c in cfgs]
    for rs, H in zip(ref_sids, deltas):
        ref.update(rs, H)

    svc = SketchService(mesh=make_grid_mesh(1, 1, 1))
    sids = [svc.open(c) for c in cfgs]
    # middle lane fails ONCE: attempt 1 lands lane 0 then dies; the retry
    # must start at lane 1, not lane 0
    faults.arm("ingest.dispatch_lane", exc=faults.FaultInjected, times=1,
               match={"sid": sids[1]})
    with IngestQueue(svc, max_retries=2, backoff_base=0.0) as q:
        q.hold()                      # one batch -> one 3-lane round
        for sid, H in zip(sids, deltas):
            q.submit(sid, H)
        q.release()
        q.flush(raise_errors=True)
        st = q.stats()
    assert st["retries"] == 1 and st["quarantined"] == 0
    assert st["applied"] == 3 and st["errors"] == 0
    for sid, rs in zip(sids, ref_sids):
        np.testing.assert_array_equal(np.asarray(svc.sketch(sid)),
                                      np.asarray(ref.sketch(rs)))


def test_distributed_poison_lane_excised_exactly_once():
    """Retries exhaust on a persistently-poison lane mid-round: the
    fallback excises only that lane, and the lanes that landed before the
    first failure are NOT re-applied by the fallback."""
    from repro.core.sketch import make_grid_mesh

    rng = np.random.default_rng(7)
    cfgs = [StreamConfig(n1=32, n2=16, r=4, seed=s, corange=False)
            for s in range(3)]
    deltas = [rng.standard_normal((32, 16)).astype("float32")
              for _ in range(3)]

    ref = SketchService(mesh=make_grid_mesh(1, 1, 1))
    ref_sids = [ref.open(c) for c in cfgs]
    for rs, H in zip(ref_sids, deltas):
        ref.update(rs, H)

    svc = SketchService(mesh=make_grid_mesh(1, 1, 1))
    sids = [svc.open(c) for c in cfgs]
    bad = sids[1]
    faults.arm("ingest.dispatch_lane", exc=faults.FaultInjected,
               times=None, match={"sid": bad})
    faults.arm("ingest.apply_lane", exc=faults.FaultInjected,
               times=None, match={"sid": bad})
    with IngestQueue(svc, max_retries=1, backoff_base=0.0) as q:
        q.hold()
        for sid, H in zip(sids, deltas):
            q.submit(sid, H)
        q.release()
        applied = q.flush()
        st = q.stats()
    assert applied == 2 and st["quarantined"] == 1 and st["errors"] == 1
    # healthy lanes land exactly once — bitwise vs the undisturbed run
    for sid, rs in zip(sids, ref_sids):
        if sid != bad:
            np.testing.assert_array_equal(np.asarray(svc.sketch(sid)),
                                          np.asarray(ref.sketch(rs)))
    # the poison lane never touched its accumulators
    fresh = SketchService(mesh=make_grid_mesh(1, 1, 1))
    fsid = fresh.open(cfgs[1])
    np.testing.assert_array_equal(np.asarray(svc.sketch(bad)),
                                  np.asarray(fresh.sketch(fsid)))


def test_submit_rejects_row0_on_mesh():
    """A row-block submit against a distributed service is rejected at
    submit time with service.update's semantics — never silently applied
    as an additive delta at row 0."""
    from repro.core.sketch import make_grid_mesh

    svc = SketchService(mesh=make_grid_mesh(1, 1, 1))
    sid = svc.open(StreamConfig(n1=32, n2=16, r=4, seed=0, corange=False))
    with IngestQueue(svc) as q:
        with pytest.raises(ValueError, match="row0"):
            q.submit(sid, np.ones((4, 16), np.float32), 3)
        q.submit(sid, np.ones((32, 16), np.float32))   # row0=0 flows
        q.flush(raise_errors=True)
        st = q.stats()
    assert st["rejected"] == 1 and st["applied"] == 1


def test_wal_replay_distributed_additive_and_watermark(tmp_path):
    """Replay onto a distributed service: records apply as full-shape
    additive updates (row0 dropped, as live distributed ingest would),
    bitwise; the reopened journal's watermark advances so the recovered
    prefix resolves; a journaled local-mode row slab is refused."""
    from repro.core.sketch import make_grid_mesh

    rng = np.random.default_rng(8)
    cfg = StreamConfig(n1=32, n2=16, r=4, seed=9, corange=False)
    deltas = [rng.standard_normal((32, 16)).astype("float32")
              for _ in range(3)]

    ref = SketchService(mesh=make_grid_mesh(1, 1, 1))
    rsid = ref.open(cfg)
    for H in deltas:
        ref.update(rsid, H)

    path = str(tmp_path / "ingest.wal")
    with wal_mod.WriteAheadLog(path) as wal:
        for H in deltas:
            wal.append(0, 0, H)
    # crash + reopen: the watermark restarts at 0, every record pending
    wal2 = wal_mod.WriteAheadLog(path)
    assert wal2.depth == 3
    svc = SketchService(mesh=make_grid_mesh(1, 1, 1))
    sid = svc.open(cfg)
    nrec, words = wal_mod.replay(wal2, svc, sid_map={0: sid})
    assert nrec == 3 and words == sum(H.size for H in deltas)
    assert wal2.watermark == 3 and wal2.depth == 0
    assert wal2.truncate() == 0       # replayed prefix is droppable
    np.testing.assert_array_equal(np.asarray(svc.sketch(sid)),
                                  np.asarray(ref.sketch(rsid)))
    # a row slab journaled by a LOCAL service cannot be misapplied here
    wal2.append(0, 5, rng.standard_normal((4, 16)).astype("float32"))
    with pytest.raises(ValueError, match="row0"):
        wal_mod.replay(wal2, svc, sid_map={0: sid})
    wal2.close()


def test_wal_reopen_replay_restores_watermark_for_new_queue(tmp_path):
    """After crash recovery, a NEW IngestQueue attached to the replayed
    journal must be able to advance the watermark past the pre-crash
    seqnos: new submits resolve, truncate drops everything, depth
    returns to 0 (no unbounded journal growth)."""
    rng = np.random.default_rng(9)
    cfg = StreamConfig(n1=64, n2=32, r=4, seed=3, corange=False)
    traffic = _mk_traffic(rng, 1, 4, cfg.n1, cfg.n2)
    ref_Y = _reference([cfg], traffic)[0]

    path = str(tmp_path / "ingest.wal")
    with wal_mod.WriteAheadLog(path) as wal:
        for _, H, row0 in traffic[:3]:      # pre-crash: journaled, unapplied
            wal.append(0, row0, H)

    wal2 = wal_mod.WriteAheadLog(path)      # recovery: reopen + replay
    svc = SketchService()
    sid = svc.open(cfg)
    nrec, _ = wal_mod.replay(wal2, svc, sid_map={0: sid})
    assert nrec == 3
    assert wal2.watermark == 3 and wal2.depth == 0
    with IngestQueue(svc, wal=wal2, wal_truncate_every=1) as q:
        _, H, row0 = traffic[3]
        assert q.submit(sid, H, row0) == 4  # seqnos resume past the prefix
        q.flush(raise_errors=True)
    assert wal2.depth == 0                  # watermark caught up
    assert wal2.truncate() == 0             # journal fully droppable
    wal2.close()
    np.testing.assert_array_equal(np.asarray(svc.sketch(sid)), ref_Y)


def test_submit_blocked_on_full_queue_fails_fast_on_worker_death():
    """The fast-fail contract has to hold for a producer ALREADY blocked
    on a full queue: the worker dying cannot wake queue.Queue.put, so
    submit must poll liveness between short waits and raise WorkerDied
    instead of hanging forever."""
    svc = SketchService()
    sid = svc.open(StreamConfig(n1=32, n2=16, r=4, seed=0, corange=False))
    H = np.ones((4, 16), np.float32)
    entered, block = threading.Event(), threading.Event()

    def killer(**ctx):
        entered.set()
        block.wait(timeout=30.0)
        raise faults.WorkerKilled("chaos: worker dies with the queue full")

    faults.arm("ingest.apply_round", handler=killer, times=None)
    q = IngestQueue(svc, depth=1)
    q.submit(sid, H, 0)                  # worker takes it, parks in killer
    assert entered.wait(30.0)
    q.submit(sid, H, 0)                  # refills the depth-1 queue
    result = {}

    def blocked_submit():
        try:
            q.submit(sid, H, 0)          # full queue: blocks (backpressure)
            result["exc"] = None
        except BaseException as e:
            result["exc"] = e

    t = threading.Thread(target=blocked_submit)
    t.start()
    time.sleep(0.2)
    assert t.is_alive()                  # genuinely blocked, not failed
    block.set()                          # the worker now dies mid-round
    t.join(30.0)
    assert not t.is_alive()
    assert isinstance(result["exc"], WorkerDied)
    q.shutdown()


def test_worker_died_fast_fail_and_idempotent_shutdown():
    svc = SketchService()
    sid = svc.open(StreamConfig(n1=32, n2=16, r=4, seed=0, corange=False))
    H = np.ones((4, 16), np.float32)
    faults.arm("ingest.apply_round", exc=faults.WorkerKilled, times=None)
    q = IngestQueue(svc)
    q.submit(sid, H, 0)
    deadline = time.monotonic() + 30.0
    while q.worker_alive and time.monotonic() < deadline:
        time.sleep(0.01)
    assert not q.worker_alive
    # every entry point fails FAST with the original traceback attached
    with pytest.raises(WorkerDied) as ei:
        q.submit(sid, H, 0)
    assert "WorkerKilled" in ei.value.traceback_text
    with pytest.raises(WorkerDied):
        q.flush()
    with pytest.raises(WorkerDied):
        q.close_stream(sid)
    assert q.heartbeat_age() >= 0.0
    assert q.stats()["worker_alive"] is False
    q.shutdown()
    q.shutdown()                      # joining a corpse is a no-op


# ---------------------------------------------------------------------------
# chaos driver scenarios (the launch/serve.py --chaos drills)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("scenario", ["torn-write", "eviction-storm"])
def test_chaos_scenarios_recover(scenario, tmp_path):
    out = faults.run_chaos_scenario(scenario, n1=64, n2=32, r=4, streams=3,
                                    updates=2, workdir=str(tmp_path),
                                    verbose=False)
    assert out["recovered"], out
