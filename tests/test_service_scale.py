"""Multi-tenant serving harness (PR 6): shape-bucketed ragged ingest,
QoS admission/eviction, and the bounded async ingest queue.

Contract pillars:
  (a) THE fixed oracle — lane i of a bucketed ragged batch is bitwise the
      result of updating stream i alone via ``update``, across bucket
      mixes × row0 offsets × kinds × dtypes × fold backends, including
      the padded/masked tail (proved dead with an all-NaN pad probe);
  (b) fault injection on the async queue — backpressure instead of drops,
      close-with-inflight drains cleanly, non-finite payloads rejected
      before touching (Y, W), evicted-then-touched restores bitwise (host
      memory AND disk spill);
  (c) service ledger — ``stats()["updates"]`` survives ``close``;
      ``close``/``evict`` on unknown sids raise clear ValueErrors;
  (d) the bucket-edge planner's limit behaviors (zero dispatch overhead →
      one bucket per distinct height; dominant overhead → one bucket).

Uses the shared hypothesis shim (tests/_hypothesis_compat): real
hypothesis when installed, the deterministic fallback otherwise.
"""
import dataclasses
import queue as pyqueue
import time

import numpy as np
import pytest

import jax.numpy as jnp

from _hypothesis_compat import given, settings, st

from repro.plan import PRESETS, choose_bucket_edges, ragged_bucket_cost
from repro.stream import (
    IngestQueue,
    SketchService,
    StreamConfig,
    pow2_bucket,
    snap_bucket,
)


def bits_equal(a, b):
    a, b = np.asarray(a), np.asarray(b)
    assert a.dtype == b.dtype and a.shape == b.shape
    return np.array_equal(a.view(np.uint8), b.view(np.uint8))


def make_cfg(seed, kind="normal", dtype="float32", n1=96, n2=64, r=8,
             corange=True):
    return StreamConfig(n1=n1, n2=n2, r=r, seed=seed, kind=kind,
                        dtype=dtype, corange=corange)


def ragged_traffic(rng, cfgs, max_k=32):
    """One (sid-index, H, row0) item per config, heights/offsets random."""
    items = []
    for i, c in enumerate(cfgs):
        k = int(rng.integers(1, max_k + 1))
        row0 = int(rng.integers(0, c.n1 - k + 1))
        H = rng.standard_normal((k, c.n2)).astype(np.float32)
        items.append((i, H, row0))
    return items


# ---------------------------------------------------------------------------
# (a) the fixed oracle: ragged lane == solo update, bitwise
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       kind=st.sampled_from(["normal", "uniform", "rademacher"]),
       dtype=st.sampled_from(["float32", "bfloat16"]),
       max_k=st.integers(1, 48),
       n_streams=st.integers(1, 7))
def test_ragged_lane_bitwise_equals_solo_update(seed, kind, dtype, max_k,
                                                n_streams):
    rng = np.random.default_rng(seed)
    cfgs = [make_cfg(seed + i, kind=kind, dtype=dtype)
            for i in range(n_streams)]
    svc, ref = SketchService(), SketchService()
    sids = [svc.open(c) for c in cfgs]
    rids = [ref.open(c) for c in cfgs]
    items = ragged_traffic(rng, cfgs, max_k=max_k)
    for i, H, row0 in items:
        ref.update(rids[i], H, row0=row0)
    svc.update_ragged([(sids[i], H, row0) for i, H, row0 in items],
                      pad_value=float("nan"))   # the all-NaN pad probe
    for i in range(n_streams):
        assert bits_equal(svc.sketch(sids[i]), ref.sketch(rids[i])), \
            f"Y lane {i} diverged from solo update"
        assert bits_equal(svc.corange(sids[i]), ref.corange(rids[i])), \
            f"W lane {i} diverged from solo update"


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       kind=st.sampled_from(["normal", "rademacher"]))
def test_ragged_lane_bitwise_pallas_fold_backend(seed, kind):
    """The vmapped *Pallas* masked fold (interpret mode off-TPU) hits the
    same bits as the jnp fold and as the solo update — the fold is
    backend-bitwise by construction (same ops, same operands)."""
    rng = np.random.default_rng(seed)
    cfgs = [make_cfg(seed + i, kind=kind) for i in range(3)]
    ref = SketchService()
    rids = [ref.open(c) for c in cfgs]
    items = ragged_traffic(rng, cfgs)
    for i, H, row0 in items:
        ref.update(rids[i], H, row0=row0)
    for backend in ("jnp", "pallas"):
        svc = SketchService(backend=backend)
        sids = [svc.open(c) for c in cfgs]
        svc.update_ragged([(sids[i], H, row0) for i, H, row0 in items],
                          pad_value=float("nan"))
        for i in range(3):
            assert bits_equal(svc.sketch(sids[i]), ref.sketch(rids[i])), \
                f"{backend} Y lane {i}"
            assert bits_equal(svc.corange(sids[i]), ref.corange(rids[i])), \
                f"{backend} W lane {i}"


def test_ragged_mixed_signatures_and_repeat_batches():
    """Streams with different signatures (corange on/off, dtypes) fuse in
    one update_ragged call — grouping is by (signature, bucket) — and a
    second ragged batch composes bitwise on top of the first."""
    rng = np.random.default_rng(7)
    cfgs = [make_cfg(1), make_cfg(2, dtype="bfloat16"),
            make_cfg(3, corange=False), make_cfg(4, kind="rademacher")]
    svc, ref = SketchService(), SketchService()
    sids = [svc.open(c) for c in cfgs]
    rids = [ref.open(c) for c in cfgs]
    for _ in range(2):
        items = ragged_traffic(rng, cfgs)
        for i, H, row0 in items:
            ref.update(rids[i], H, row0=row0)
        svc.update_ragged([(sids[i], H, row0) for i, H, row0 in items],
                          pad_value=float("nan"))
    for i, c in enumerate(cfgs):
        assert bits_equal(svc.sketch(sids[i]), ref.sketch(rids[i]))
        if c.corange:
            assert bits_equal(svc.corange(sids[i]), ref.corange(rids[i]))


def test_ragged_respects_planner_bucket_edges():
    """Explicit bucket_edges steer the padding; bits never change."""
    rng = np.random.default_rng(11)
    cfgs = [make_cfg(20 + i) for i in range(5)]
    svc, ref = SketchService(), SketchService()
    sids = [svc.open(c) for c in cfgs]
    rids = [ref.open(c) for c in cfgs]
    items = ragged_traffic(rng, cfgs, max_k=48)
    for i, H, row0 in items:
        ref.update(rids[i], H, row0=row0)
    svc.update_ragged([(sids[i], H, row0) for i, H, row0 in items],
                      bucket_edges=[8, 48], pad_value=float("nan"))
    for i in range(5):
        assert bits_equal(svc.sketch(sids[i]), ref.sketch(rids[i]))


def test_ragged_validates_before_mutating():
    svc = SketchService()
    cfg = make_cfg(5)
    a, b = svc.open(cfg), svc.open(cfg)
    H = np.ones((4, cfg.n2), np.float32)
    before = np.asarray(svc.sketch(a)).copy()
    with pytest.raises(ValueError):
        svc.update_ragged([(a, H, 0), (b, H, cfg.n1)])   # lane b out of range
    assert bits_equal(svc.sketch(a), before), \
        "a bad lane must not leave a half-applied batch"
    with pytest.raises(ValueError):
        svc.update_ragged([(a, H, 0), (a, H, 0)])        # duplicate sid
    with pytest.raises(ValueError):
        svc.update_ragged([])


def test_bucket_snapping_helpers():
    assert [pow2_bucket(k) for k in (1, 2, 3, 5, 8, 9, 64)] == \
        [1, 2, 4, 8, 8, 16, 64]
    assert snap_bucket(5, [4, 16]) == 16
    # taller than every edge: pow2 fallback so over-tall traffic shares
    # programs instead of compiling one per distinct height (PR 10)
    assert snap_bucket(17, [4, 16]) == 32
    assert snap_bucket(3, None) == 4
    # height 1 is never padded, whatever the edges say (see below)
    assert snap_bucket(1, [8, 32]) == 1
    assert snap_bucket(1, None) == 1


def test_height1_lane_never_padded_and_stays_bitwise_at_large_n2():
    # XLA-CPU lowers an M=1 matmul through a gemv kernel whose
    # K-reduction order differs from the packed M>=2 gemm loop, so a
    # single-row slab padded into a taller bucket loses bitwise equality
    # with its solo update once the contraction is large (regression:
    # n2=512 traffic with k=1 lanes under planner edges [8, 32]).
    # snap_bucket therefore gives height 1 its own bucket, and the
    # planner emits the mandatory [1] edge.
    assert choose_bucket_edges([1, 1, 4, 9], 512, 32)[0] == 1
    n1, n2, r = 64, 512, 32
    cfgs = [make_cfg(70 + i, n1=n1, n2=n2, r=r) for i in range(3)]
    svc, ref = SketchService(), SketchService()
    sids = [svc.open(c) for c in cfgs]
    rids = [ref.open(c) for c in cfgs]
    rng = np.random.default_rng(9)
    hs = [rng.standard_normal((k, n2)).astype(np.float32)
          for k in (1, 3, 8)]
    svc.update_ragged([(sids[i], hs[i], 2 * i) for i in range(3)],
                      bucket_edges=[8])
    for i in range(3):
        ref.update(rids[i], hs[i], row0=2 * i)
    for i in range(3):
        assert bits_equal(svc.sketch(sids[i]), ref.sketch(rids[i]))
        assert bits_equal(svc.corange(sids[i]), ref.corange(rids[i]))


# ---------------------------------------------------------------------------
# (b) fault injection on the async ingest queue
# ---------------------------------------------------------------------------

def test_queue_applies_updates_bitwise_and_preserves_per_stream_order():
    rng = np.random.default_rng(3)
    cfgs = [make_cfg(30 + i) for i in range(3)]
    svc, ref = SketchService(), SketchService()
    sids = [svc.open(c) for c in cfgs]
    rids = [ref.open(c) for c in cfgs]
    with IngestQueue(svc, depth=32, window=8) as q:
        for t in range(9):                      # 3 updates per stream:
            i = t % 3                           # order within a stream matters
            k = int(rng.integers(1, 17))
            row0 = int(rng.integers(0, cfgs[i].n1 - k + 1))
            H = rng.standard_normal((k, cfgs[i].n2)).astype(np.float32)
            q.submit(sids[i], H, row0)
            ref.update(rids[i], H, row0=row0)
        q.flush(raise_errors=True)
        st = q.stats()
        assert st["applied"] == 9 and st["errors"] == 0
        for i in range(3):
            assert bits_equal(svc.sketch(sids[i]), ref.sketch(rids[i]))


def test_queue_full_applies_backpressure_not_drops():
    svc = SketchService()
    sid = svc.open(make_cfg(40))
    q = IngestQueue(svc, depth=4, window=8)
    try:
        q.submit(sid, np.ones((2, 64), np.float32), 0)
        q.flush()
        q.hold()                      # stall the worker deterministically
        time.sleep(0.1)               # let its in-flight get() time out
        for _ in range(4):
            q.submit(sid, np.ones((2, 64), np.float32), 0)
        with pytest.raises(pyqueue.Full):
            q.submit(sid, np.ones((2, 64), np.float32), 0, timeout=0.2)
        q.release()
        q.flush(raise_errors=True)
        assert q.stats()["applied"] == 5, "held updates must not be dropped"
    finally:
        q.shutdown()


def test_queue_rejects_nonfinite_before_touching_state():
    svc = SketchService()
    sid = svc.open(make_cfg(41))
    with IngestQueue(svc, depth=8, window=4) as q:
        q.submit(sid, np.ones((2, 64), np.float32), 0)
        q.flush(raise_errors=True)
        before = np.asarray(svc.sketch(sid)).copy()
        for bad in (np.nan, np.inf, -np.inf):
            with pytest.raises(ValueError):
                q.submit(sid, np.full((2, 64), bad, np.float32), 0)
        q.flush(raise_errors=True)
        assert bits_equal(svc.sketch(sid), before)
        st = q.stats()
        assert st["rejected"] == 3 and st["applied"] == 1


def test_queue_close_with_inflight_drains_cleanly():
    rng = np.random.default_rng(5)
    cfg = make_cfg(42)
    svc, ref = SketchService(), SketchService()
    sid, rid = svc.open(cfg), ref.open(cfg)
    q = IngestQueue(svc, depth=64, window=8)
    try:
        q.hold()
        time.sleep(0.1)
        for j in range(6):
            k = int(rng.integers(1, 9))
            H = rng.standard_normal((k, cfg.n2)).astype(np.float32)
            q.submit(sid, H, j * 8)
            ref.update(rid, H, row0=j * 8)
        q.release()
        Y, W = q.close_stream(sid)    # must drain all 6 first
        assert bits_equal(Y, ref.sketch(rid))
        assert bits_equal(W, ref.corange(rid))
        with pytest.raises(ValueError):
            q.submit(sid, np.ones((2, cfg.n2), np.float32), 0)
        assert q.stats()["errors"] == 0
    finally:
        q.shutdown()


def test_queue_worker_errors_are_surfaced_not_swallowed():
    svc = SketchService()
    cfg = make_cfg(43)
    sid = svc.open(cfg)
    with IngestQueue(svc, depth=8, window=4, validate_payloads=False) as q:
        svc.close(sid)                # race: sid dies under the queue
        q.submit(sid, np.ones((2, cfg.n2), np.float32), 0)
        with pytest.raises(RuntimeError, match="ingest failure"):
            q.flush(raise_errors=True)
        assert q.stats()["errors"] == 1


# ---------------------------------------------------------------------------
# (b') QoS admission/eviction: transparent bitwise restore
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("spill", ["host", "disk"])
def test_evicted_then_touched_restores_bitwise(spill, tmp_path):
    rng = np.random.default_rng(9)
    cfg = make_cfg(50)
    svc = SketchService(max_resident=2,
                        spill_dir=str(tmp_path) if spill == "disk" else None)
    ref = SketchService()
    sid, rid = svc.open(cfg), ref.open(cfg)
    H = rng.standard_normal((16, cfg.n2)).astype(np.float32)
    svc.update(sid, H, row0=8)
    ref.update(rid, H, row0=8)
    svc.evict(sid)
    assert svc.num_evicted == 1 and svc.num_resident == 0
    # touch via a ragged batch: restore must be transparent AND bitwise
    H2 = rng.standard_normal((5, cfg.n2)).astype(np.float32)
    svc.update_ragged([(sid, H2, 40)], pad_value=float("nan"))
    ref.update(rid, H2, row0=40)
    assert svc.num_evicted == 0 and svc.num_resident == 1
    assert bits_equal(svc.sketch(sid), ref.sketch(rid))
    assert bits_equal(svc.corange(sid), ref.corange(rid))


def test_admission_evicts_lru_respecting_qos():
    cfg = make_cfg(51)
    svc = SketchService(max_resident=2)
    pinned = svc.open(cfg, qos="pinned")
    best = svc.open(cfg, qos="best_effort")
    svc.sketch(best)                        # best_effort is the HOTTEST...
    std = svc.open(cfg, qos="standard")     # ...but lowest class evicts first
    assert svc.num_resident == 2
    assert set(svc._streams) == {pinned, std}
    # pinned survives even as LRU; standard (colder class wins over recency)
    svc.sketch(std)
    again = svc.open(cfg, qos="standard")
    assert pinned in svc._streams and again in svc._streams
    # all-pinned refusal is loud, not corrupting
    svc2 = SketchService(max_resident=1)
    svc2.open(cfg, qos="pinned")
    with pytest.raises(RuntimeError, match="admission refused"):
        svc2.open(cfg, qos="pinned")


def test_batch_lanes_are_protected_from_mutual_eviction():
    cfg = make_cfg(52)
    svc = SketchService(max_resident=1)
    a = svc.open(cfg)
    b = svc.open(cfg)                 # evicts a
    assert svc.num_evicted == 1
    rng = np.random.default_rng(0)
    items = [(s, rng.standard_normal((4, cfg.n2)).astype(np.float32), 0)
             for s in (a, b)]
    # both lanes cannot be resident under max_resident=1: the batch must
    # refuse admission rather than evict its own in-flight sibling
    with pytest.raises(RuntimeError, match="admission refused"):
        svc.update_ragged(items)


def test_close_works_on_evicted_streams(tmp_path):
    cfg = make_cfg(53)
    for spill in (None, str(tmp_path)):
        svc = SketchService(max_resident=1, spill_dir=spill)
        ref = SketchService()
        a, ra = svc.open(cfg), ref.open(cfg)
        H = np.random.default_rng(1).standard_normal(
            (8, cfg.n2)).astype(np.float32)
        svc.update(a, H, row0=0)
        ref.update(ra, H, row0=0)
        svc.open(cfg)                 # evicts a
        Y, W = svc.close(a)
        assert bits_equal(Y, ref.sketch(ra))
        assert bits_equal(W, ref.corange(ra))
        assert svc.num_streams == 1


# ---------------------------------------------------------------------------
# (c) service ledger fixes
# ---------------------------------------------------------------------------

def test_stats_updates_is_a_lifetime_counter():
    svc = SketchService()
    cfg = make_cfg(60)
    a, b = svc.open(cfg), svc.open(cfg)
    H = np.ones((4, cfg.n2), np.float32)
    svc.update(a, H, row0=0)
    svc.update_ragged([(a, H, 8), (b, H, 0)])
    assert svc.stats()["updates"] == 3
    svc.close(a)
    assert svc.stats()["updates"] == 3, \
        "closing a stream must not erase its updates from the ledger"
    svc.close(b)
    assert svc.stats()["updates"] == 3


def test_unknown_sid_raises_clear_value_error():
    svc = SketchService()
    sid = svc.open(make_cfg(61))
    svc.close(sid)
    for op in (lambda: svc.close(sid),
               lambda: svc.close(999),
               lambda: svc.evict(999),
               lambda: svc.update(sid, np.ones((4, 64), np.float32), row0=0),
               lambda: svc.sketch(999)):
        with pytest.raises(ValueError, match="unknown stream id"):
            op()


def test_stats_reports_residency():
    svc = SketchService(max_resident=1)
    cfg = make_cfg(62)
    svc.open(cfg), svc.open(cfg)
    st = svc.stats()
    assert st["streams"] == 2 and st["resident"] == 1 and st["evicted"] == 1


# ---------------------------------------------------------------------------
# (d) bucket-edge planner limits
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999), n=st.integers(1, 12))
def test_choose_bucket_edges_covers_all_heights(seed, n):
    rng = np.random.default_rng(seed)
    ks = [int(rng.integers(1, 65)) for _ in range(n)]
    edges = choose_bucket_edges(ks, 256, 16, machine=PRESETS["cpu"])
    assert edges == sorted(edges)
    assert edges[-1] == max(ks), "tallest lane must fit the last bucket"
    for k in ks:
        assert snap_bucket(k, edges) >= k


def test_choose_bucket_edges_limit_behaviors():
    ks = [3, 3, 7, 8, 8, 17, 31, 32]
    cpu = PRESETS["cpu"]
    free = dataclasses.replace(cpu, dispatch_overhead=0.0)
    assert choose_bucket_edges(ks, 256, 16, machine=free) == \
        sorted(set(ks)), "zero dispatch cost -> padding is never worth it"
    dominant = dataclasses.replace(cpu, dispatch_overhead=1e3)
    assert choose_bucket_edges(ks, 256, 16, machine=dominant) == [32], \
        "dominant dispatch cost -> one fused bucket"
    assert choose_bucket_edges([], 256, 16, machine=cpu) == []
    # the DP's objective really is the bucket-cost sum it claims to minimize
    edges = choose_bucket_edges(ks, 256, 16, machine=cpu)
    def total(edgeset):
        groups = {}
        for k in ks:
            groups.setdefault(snap_bucket(k, edgeset), []).append(k)
        return sum(ragged_bucket_cost(g, kb, 256, 16, 33, machine=cpu)
                   for kb, g in groups.items())
    assert total(edges) <= total(sorted(set(ks))) + 1e-12
    assert total(edges) <= total([max(ks)]) + 1e-12


# ---------------------------------------------------------------------------
# dtype edge: bf16 lanes through the ragged path keep native accumulation
# ---------------------------------------------------------------------------

def test_ragged_bf16_matches_solo_bf16_exactly():
    rng = np.random.default_rng(77)
    cfg = make_cfg(70, dtype="bfloat16")
    svc, ref = SketchService(), SketchService()
    sid, rid = svc.open(cfg), ref.open(cfg)
    H = rng.standard_normal((12, cfg.n2)).astype(np.float32)
    svc.update_ragged([(sid, H, 3)], pad_value=float("nan"))
    ref.update(rid, H, row0=3)
    assert svc.sketch(sid).dtype == jnp.bfloat16
    assert bits_equal(svc.sketch(sid), ref.sketch(rid))
    assert bits_equal(svc.corange(sid), ref.corange(rid))
