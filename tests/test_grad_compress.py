"""Planner-priced sketched gradient compression (docs/TRAINING.md).

Pins the PR-8 contracts end to end:
  * planner decision property — compress iff r_eff·(m+n) < m·n, and the
    plan's exchange_words equals comm_words_compressed under its own
    decision tree;
  * gemm_block and the full compressed exchange are bitwise-identical
    across backend="jnp"|"pallas" on untiled leaves;
  * reshard_error_fb preserves the per-leaf worker mean (the only
    statistic the exchange sees — pmean is linear in the error state);
  * on 8 fake devices the comm ledger measures EXACTLY the words the
    planner priced (drift 0, bound_fraction 1);
  * error_fb checkpoints round-trip: bitwise-identical next step on a
    same-width mesh, matching trajectory (f32 reduction order) on a
    narrower one.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from dist_helper import run_distributed
from repro.core.compat import shard_map
from repro.parallel.grad_compress import (comm_words_compressed,
                                          comm_words_exact,
                                          compress_and_allreduce,
                                          init_error_fb, local_fb,
                                          reshard_error_fb, stack_fb)
from repro.plan import (explain_train_compression, grad_allreduce_cost,
                        grad_compress_cost, plan_train_compression)


# ---------------------------------------------------------------- planner

SHAPE_GRID = [(4, 4), (2, 2), (16, 64), (64, 16), (100, 7), (7, 100),
              (1024, 512), (32, 16), (3, 3, 64)]
RANK_GRID = [1, 2, 8, 64]


def test_planner_decision_property():
    """Compress exactly when the sketched exchange moves fewer words:
    r_eff·(m+n) < m·n with r_eff = min(rank, m, n)."""
    for shape in SHAPE_GRID:
        for rank in RANK_GRID:
            tree = {"w": jax.ShapeDtypeStruct(shape, jnp.float32),
                    "b": jax.ShapeDtypeStruct((shape[-1],), jnp.float32)}
            plan = plan_train_compression(tree, rank=rank, P=8)
            by_name = {d.name: d for d in plan.decisions}
            m = math.prod(shape[:-1])
            n = shape[-1]
            r_eff = min(rank, m, n)
            want = r_eff * (m + n) < m * n
            d = by_name["w"]
            assert d.compress == want, (shape, rank, d)
            assert d.r_eff == r_eff
            assert not by_name["b"].compress      # vectors never compress
            # the plan's word total is the runtime's word count
            assert plan.exchange_words == comm_words_compressed(
                tree, rank, decisions=plan.decision_tree())
            assert plan.raw_words == comm_words_exact(tree)
            assert plan.exchange_words <= plan.raw_words
            assert plan.lower_bound_words == plan.exchange_words


def test_planner_costs_match_paper_arithmetic():
    # raw all-reduce: m·n words regardless of rank
    assert grad_allreduce_cost(1024, 1024, world=8).words == 1024 * 1024
    # sketched: r·(m+n) — Omega costs zero (Thm 2 regime 1)
    assert grad_compress_cost(1024, 1024, 8, world=8).words == 8 * 2048
    # single worker: nothing moves either way
    assert grad_allreduce_cost(64, 64, world=1).words == 0
    assert grad_compress_cost(64, 64, 8, world=1).words == 0


def test_planner_explain_renders_table():
    tree = {"emb": jax.ShapeDtypeStruct((256, 64), jnp.float32),
            "scale": jax.ShapeDtypeStruct((64,), jnp.float32)}
    plan = plan_train_compression(tree, rank=4, P=8)
    text = explain_train_compression(plan)
    assert "emb" in text and "scale" in text
    assert "sketch" in text and "raw" in text
    assert "totals:" in text        # savings line present


# ---------------------------------------------------------------- kernels

@pytest.mark.parametrize("alpha", [1.0, -1.0, 0.5])
@pytest.mark.parametrize("use_acc", [False, True])
def test_gemm_block_backend_parity(alpha, use_acc):
    """Untiled (single exact tile) pallas interpret == jnp, bitwise."""
    from repro.kernels.local import gemm_block
    k1, k2, k3 = jax.random.split(jax.random.key(0), 3)
    A = jax.random.normal(k1, (17, 9), jnp.float32)
    B = jax.random.normal(k2, (9, 5), jnp.float32)
    acc = jax.random.normal(k3, (17, 5), jnp.float32) if use_acc else None
    ref = gemm_block(A, B, alpha=alpha, acc=acc, backend="jnp")
    got = gemm_block(A, B, alpha=alpha, acc=acc, backend="pallas")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


def test_compressed_exchange_backend_bitwise():
    """Full compress_and_allreduce, jnp vs pallas: bitwise-identical
    mean-gradient estimate AND error feedback on untiled leaves."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    key = jax.random.key(7)
    ks = jax.random.split(key, 3)
    grads = {"w": jax.random.normal(ks[0], (17, 9), jnp.float32),
             "v": jax.random.normal(ks[1], (33, 5), jnp.float32),
             "b": jax.random.normal(ks[2], (9,), jnp.float32)}
    fb = init_error_fb(grads, rank=3, min_dim=1)
    # a non-zero residual so the acc-fused path is exercised
    fb = jax.tree_util.tree_map(
        lambda e: e + 0.25 if e.ndim else e, fb)

    def run(backend):
        def body(g, e):
            return compress_and_allreduce(
                g, e, step=jnp.int32(5), rank=3, min_dim=1,
                axis_name="data", backend=backend)
        specs = jax.tree_util.tree_map(lambda _: P(), (grads, fb))
        f = shard_map(body, mesh=mesh, in_specs=specs,
                      out_specs=specs, check_vma=False)
        return f(grads, fb)

    g_jnp, e_jnp = run("jnp")
    g_pl, e_pl = run("pallas")
    for a, b in zip(jax.tree_util.tree_leaves((g_jnp, e_jnp)),
                    jax.tree_util.tree_leaves((g_pl, e_pl))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- error_fb

def _mean_over_world(fb):
    return jax.tree_util.tree_map(lambda x: np.asarray(x).mean(axis=0), fb)


@pytest.mark.parametrize("world_to", [4, 2, 8, 16, 3, 1])
def test_reshard_error_fb_preserves_mean(world_to):
    key = jax.random.key(3)
    fb = {"w": jax.random.normal(key, (8, 17, 9), jnp.float32),
          "b": jnp.arange(8, dtype=jnp.float32)}
    out = reshard_error_fb(fb, 8, world_to)
    for name in fb:
        x = np.asarray(out[name])
        lead = x.shape[0] if world_to > 1 else None
        if world_to > 1:
            assert lead == world_to
            got = x.mean(axis=0)
        else:
            got = x
        np.testing.assert_allclose(got, _mean_over_world(fb)[name],
                                   rtol=1e-6, atol=1e-6)


def test_reshard_error_fb_same_width_is_identity():
    fb = {"w": jnp.arange(8 * 6, dtype=jnp.float32).reshape(8, 6)}
    out = reshard_error_fb(fb, 8, 8)
    assert out["w"] is fb["w"]      # bitwise-resume: untouched object


def test_local_stack_fb_roundtrip():
    fb = {"w": jnp.ones((1, 4, 4)), "s": jnp.zeros((1,))}
    loc = local_fb(fb)
    assert loc["w"].shape == (4, 4) and loc["s"].shape == ()
    back = stack_fb(loc)
    for k in fb:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(fb[k]))


def test_decisions_required_error():
    g = {"w": jnp.zeros((8, 8))}
    with pytest.raises(ValueError):
        comm_words_compressed(g, 4)       # neither decisions nor min_dim
    with pytest.raises(ValueError):
        comm_words_compressed(g, 4, decisions={"w": True, "extra": True})


# ------------------------------------------------- distributed (8 devices)

_COMMON = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import get_api
from repro.plan import plan_train_compression
from repro.train.step import init_state, make_dp_compressed_step

cfg = get_config("llama3-8b").reduced(n_layers=2, d_model=32, d_ff=64,
                                      vocab=64, head_dim=8)
api = get_api(cfg)
run = RunConfig(steps=10, grad_compress_rank=4, remat=False)
shapes = jax.eval_shape(lambda k: api.init(k, cfg), jax.random.key(0))
data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
"""


def test_ledger_audits_planned_words_8dev():
    """Acceptance criterion: the measured collective bytes of the
    compressed step equal the plan's exchange words + the loss scalar —
    drift 0, bound_fraction 1 (the factor-exchange floor is tight)."""
    out = run_distributed(_COMMON + """
from repro.obs.ledger import install_ledger
from repro.parallel.grad_compress import comm_words_compressed, \\
    comm_words_exact

plan = plan_train_compression(shapes, rank=4, P=8)
assert plan.n_compressed > 0
assert plan.n_compressed < len(plan.decisions)   # some leaves stay raw
assert plan.exchange_words == comm_words_compressed(
    shapes, 4, decisions=plan.decision_tree())
assert plan.exchange_words < comm_words_exact(shapes)

state = init_state(api, cfg, run, jax.random.key(0), world=8,
                   decisions=plan.decision_tree())
led = install_ledger()
step = make_dp_compressed_step(api, cfg, run, Mesh(
    np.asarray(jax.devices()), ("data",)), plan=plan)
pipe = Pipeline(data)
for _ in range(2):
    state, metrics = step(state, next(pipe))
site = led.site("train.dp_compressed_step")
assert site.calls == 2, site.calls
assert site.predicted_words == plan.exchange_words + 1.0
assert site.drift == 0.0, site.drift
assert site.bound_fraction == 1.0, site.bound_fraction
assert float(metrics["loss"]) < 20.0
print("OK drift", site.drift, "words", site.measured_words_per_call)
""")
    assert "OK drift 0.0" in out


def test_error_fb_checkpoint_resume_8dev():
    """Save mid-run, restore (fresh jit, same-width mesh): the next step
    is BITWISE identical.  Restore onto a 4-worker mesh via
    reshard_error_fb: same trajectory up to f32 reduction order."""
    out = run_distributed(_COMMON + """
import tempfile
from repro.checkpoint import ckpt
from repro.parallel.grad_compress import reshard_error_fb

plan = plan_train_compression(shapes, rank=4, P=8)
decisions = plan.decision_tree()
state0 = init_state(api, cfg, run, jax.random.key(0), world=8,
                    decisions=decisions)
mesh8 = Mesh(np.asarray(jax.devices()), ("data",))
step8 = make_dp_compressed_step(api, cfg, run, mesh8, plan=plan)
pipe = Pipeline(data)
batches = [next(pipe) for _ in range(3)]

state = state0
for b in batches[:2]:
    state, _ = step8(state, b)
d = tempfile.mkdtemp()
ckpt.save(d, 2, state)
ref, _ = step8(state, batches[2])            # continue in-process

restored, step_i, _ = ckpt.restore(d, state0)
assert step_i == 2 and int(restored.step) == 2
for a, b in zip(jax.tree_util.tree_leaves(restored.error_fb),
                jax.tree_util.tree_leaves(state.error_fb)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# fresh step fn = fresh jit of the same program: must be bitwise
step8b = make_dp_compressed_step(api, cfg, run, mesh8, plan=plan)
got, _ = step8b(restored, batches[2])
for a, b in zip(jax.tree_util.tree_leaves(ref),
                jax.tree_util.tree_leaves(got)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK bitwise resume")

# --- restore onto a NARROWER mesh (8 -> 4 workers) -------------------
mesh4 = Mesh(np.asarray(jax.devices()[:4]), ("data",))
plan4 = plan_train_compression(shapes, rank=4, P=4)
assert jax.tree_util.tree_leaves(plan4.decision_tree()) == \\
    jax.tree_util.tree_leaves(decisions)     # decisions are P-invariant
fb4 = reshard_error_fb(restored.error_fb, 8, 4)
state4 = restored.replace(error_fb=fb4)
step4 = make_dp_compressed_step(api, cfg, run, mesh4, plan=plan4)
got4, _ = step4(state4, batches[2])

upd = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
          for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                          jax.tree_util.tree_leaves(restored.params)))
diff = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
          for a, b in zip(jax.tree_util.tree_leaves(ref.params),
                          jax.tree_util.tree_leaves(got4.params)))
print("OK cross-mesh update", upd, "diff", diff)
assert upd > 0                                # the step actually moved
assert diff <= 0.05 * upd + 1e-7, (diff, upd)
""")
    assert "OK bitwise resume" in out
    assert "OK cross-mesh" in out
