# Tier-1 verification and benchmarks — the commands CI runs, documented
# here so they are reproducible locally.
#
#   make test        — the tier-1 suite (single CPU device in the main
#                      process; distributed tests spawn subprocesses with 8
#                      fake devices via tests/dist_helper.py)
#   make bench       — the benchmark driver (CSV to stdout)
#   make bench-smoke — tiny-shapes pass of every suite + JSON artifact
#                      (what the CI bench-smoke job runs)
#   make bench-trend — bench-smoke + trend compare vs the newest committed
#                      baseline in benchmarks/trends/ (the CI compare step)
#   make lint        — ruff (config in pyproject.toml) + the CI shard
#                      coverage assertion (the CI lint job)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export JAX_PLATFORMS ?= cpu

.PHONY: test bench bench-smoke bench-trend lint

test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run

bench-smoke:
	$(PY) -m benchmarks.run --smoke --out bench-smoke.json

bench-trend:
	$(PY) -m benchmarks.run --smoke --out bench-smoke.json --compare \
		$$(ls benchmarks/trends/BENCH_*.json | sort -V | tail -1)

lint:
	ruff check .
	ruff format --check .
	$(PY) scripts/check_ci_shards.py
