# Tier-1 verification and benchmarks — the commands CI runs, documented
# here so they are reproducible locally.
#
#   make test    — the tier-1 suite (single CPU device in the main process;
#                  distributed tests spawn subprocesses with 8 fake devices
#                  via tests/dist_helper.py)
#   make bench   — the benchmark driver (CSV to stdout)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export JAX_PLATFORMS ?= cpu

.PHONY: test bench

test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run
