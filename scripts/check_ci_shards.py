#!/usr/bin/env python
"""Assert the CI tier-1 shards cover every test file, with no double runs.

The tier-1 matrix in .github/workflows/ci.yml names explicit test files per
shard plus a generated "rest" shard that runs ``tests`` minus an --ignore
list.  The invariant this script pins (CHANGES.md calls the hazard out):

  * the rest shard's --ignore list is EXACTLY the union of the files the
    named shards run — an ignored-but-not-sharded file would silently fall
    out of tier-1, and a sharded-but-not-ignored file would run twice;
  * every file a shard names exists on disk (renames can't strand a shard);
  * every ``tests/test_*.py`` on disk therefore runs in exactly one shard
    (new files land in "rest" by construction).

Run from the repo root (the lint CI job does) or via the tier-1 test
``tests/test_ci_shards.py``.  Exits non-zero with a diff on violation.
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
CI = ROOT / ".github" / "workflows" / "ci.yml"


def parse_shards(text: str):
    """(named_shard_files, rest_ignores) from the ci.yml shard matrix."""
    named: set = set()
    ignores: set = set()
    # every "tests/test_*.py" token outside YAML comments, tagged by
    # whether it is an --ignore= argument
    code = "\n".join(line.split("#", 1)[0] for line in text.splitlines())
    for m in re.finditer(r"(--ignore=)?(tests/test_[A-Za-z0-9_]+\.py)", code):
        if m.group(1):
            ignores.add(m.group(2))
        else:
            named.add(m.group(2))
    return named, ignores


def check(ci_path: pathlib.Path = CI, root: pathlib.Path = ROOT):
    text = ci_path.read_text()
    named, ignores = parse_shards(text)
    on_disk = {f"tests/{p.name}" for p in (root / "tests").glob("test_*.py")}
    errors = []
    if named != ignores:
        only_named = sorted(named - ignores)
        only_ignored = sorted(ignores - named)
        if only_named:
            errors.append(
                f"sharded but missing from the rest --ignore list (would "
                f"run TWICE): {only_named}")
        if only_ignored:
            errors.append(
                f"ignored by the rest shard but not named by any shard "
                f"(would NEVER run): {only_ignored}")
    missing = sorted(named - on_disk)
    if missing:
        errors.append(f"shard names files that do not exist: {missing}")
    # informational: files covered only by the rest shard
    rest_only = sorted(on_disk - named)
    return errors, {"named": sorted(named), "rest_only": rest_only}


def main() -> int:
    errors, info = check()
    if errors:
        print("CI shard coverage check FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"CI shards OK: {len(info['named'])} files in named shards, "
          f"{len(info['rest_only'])} covered by the rest shard "
          f"({', '.join(info['rest_only']) or 'none'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
