"""Benchmark helpers: timing + multi-device subprocess runner."""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys
import time

SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")


def is_smoke() -> bool:
    """True when REPRO_BENCH_SMOKE=1 (set by ``run.py --smoke`` and the CI
    bench-smoke job): every suite shrinks to tiny shapes and minimal iters
    so one full pass finishes in CI minutes while still walking the exact
    measurement paths.  Subprocess snippets inherit the flag through the
    environment (``run_with_devices`` copies ``os.environ``)."""
    return os.environ.get("REPRO_BENCH_SMOKE", "") == "1"


def pick(full, smoke):
    """``full`` in normal runs, ``smoke`` under REPRO_BENCH_SMOKE=1."""
    return smoke if is_smoke() else full


def time_us(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time of fn(*args) in microseconds (block_until_ready)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run_with_devices(code: str, ndev: int = 8, timeout: int = 900) -> str:
    """Run a snippet in a subprocess with N fake devices; returns stdout.
    (The benchmark process itself keeps the default single device.)"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{proc.stderr[-2000:]}")
    return proc.stdout


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
