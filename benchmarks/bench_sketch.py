"""Paper Fig. 4 — strong scaling of B = A·Omega (Alg. 1).

P grows at fixed problem size; in the paper's regime-1 range the measured
collective traffic must be exactly zero (their 'perfect scaling' result).
Derived column: per-device collective bytes + the Theorem-2 bound.
"""
from __future__ import annotations

from .common import run_with_devices

_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp
from repro.core import rand_matmul, make_grid_mesh, select_matmul_grid, \
    matmul_lower_bound
from repro.core.sketch import input_sharding
from repro.roofline.hlo import collective_bytes_of

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
n1, n2, r = (128, 256, 16) if smoke else (1024, 2048, 64)
iters = 2 if smoke else 5
for P in (1, 2, 4, 8):
    g = select_matmul_grid(n1, n2, r, P)
    mesh = make_grid_mesh(*g.shape, devices=jax.devices()[:P])
    A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                       input_sharding(mesh))
    fn = jax.jit(lambda a: rand_matmul(a, 7, r, mesh))
    jax.block_until_ready(fn(A))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(A))
    us = (time.perf_counter() - t0) / iters * 1e6
    cb = collective_bytes_of(fn.lower(A).compile().as_text()).total
    W = matmul_lower_bound(n1, n2, r, P)
    print(f"RESULT fig4_scaling_P{P},{us:.1f},"
          f"grid={g.shape};coll_bytes={cb:.0f};thm2_words={W:.0f}")
    assert (cb == 0) == (W == 0), (cb, W)

# PR 10: the O(nnz) sparse family vs the dense Alg.-1 GEMM at 1% density
# (single device; the distributed sparse bodies are priced-only), with the
# COO payload the sparse comm model ships instead of dense tiles.
import numpy as np
from repro.core.sketch import sketch_sparse_apply
from repro.plan.model import sparse_payload_words

rng = np.random.default_rng(0)
nnz = int(0.01 * n1 * n2)
As = np.zeros((n1, n2), np.float32)
As.flat[rng.choice(n1 * n2, size=nnz, replace=False)] = 1.0
As = jnp.asarray(As)
fs = jax.jit(lambda a: sketch_sparse_apply(a, 7, r, kind="countsketch"))
jax.block_until_ready(fs(As))
t0 = time.perf_counter()
for _ in range(iters):
    jax.block_until_ready(fs(As))
us = (time.perf_counter() - t0) / iters * 1e6
print(f"RESULT sketch_sparse_apply_d1pct,{us:.1f},"
      f"nnz={nnz};payload_words={sparse_payload_words(nnz):.0f};"
      f"dense_tile_words={n1 * n2}")
"""


def main():
    out = run_with_devices(_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
