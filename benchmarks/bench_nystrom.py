"""Paper Figs. 5-8 — Redist vs No-Redist Nyström: runtime and communication
volume, including the P ~ n/r crossover of Fig. 7."""
from __future__ import annotations

from .common import run_with_devices

_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import (nystrom_no_redist, nystrom_redist, nystrom_two_grid,
                        nystrom_two_grid_fused)
from repro.core.grid import select_two_grid_executable, two_grid_axis_split
from repro.plan.model import fused_redistribute_words, redistribute_words
from repro.roofline.hlo import collective_bytes_of

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
shapes = ((256, 16), (64, 16)) if smoke else ((1024, 32), (512, 128))
iters = 2 if smoke else 5
Pn = 8
mesh = Mesh(np.asarray(jax.devices()), ("x",))
for (n, r) in shapes:                 # n/r > P  and  n/r < P (Fig. 7 sides)
    S = jax.random.normal(jax.random.key(2), (n, n))
    S = S @ S.T / n
    Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
    for name, fn in (("no_redist", nystrom_no_redist),
                     ("redist", nystrom_redist)):
        jfn = jax.jit(lambda a, f=fn: f(a, 5, r, mesh))
        jax.block_until_ready(jfn(Ssh))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(jfn(Ssh))
        us = (time.perf_counter() - t0) / iters * 1e6
        cb = collective_bytes_of(jfn.lower(Ssh).compile().as_text()).total
        print(f"RESULT fig5-7_nystrom_{name}_n{n}_r{r},{us:.1f},"
              f"coll_bytes={cb:.0f};n_over_r={n//r};P={Pn}")
    # §5.3 general two-grid: the bound-driven (p, q) pair (two meshes with
    # an explicit cross-grid redistribution of B; eager timing — the two
    # stage programs are jit-cached, the device_put between them is the
    # §5.2 Redistribute being measured)
    p, q, exact = select_two_grid_executable(n, r, Pn)
    jax.block_until_ready(nystrom_two_grid(S, 5, r, p=p, q=q)[1])
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(nystrom_two_grid(S, 5, r, p=p, q=q)[1])
    us = (time.perf_counter() - t0) / iters * 1e6
    rw = redistribute_words(n, r, p, q)
    print(f"RESULT fig5-7_nystrom_bound_driven_n{n}_r{r},{us:.1f},"
          f"p={p};q={q};exact_grids={exact};redist_words={rw:.0f}")
    # single-jit fused two-grid: same (p, q), but both stages plus the
    # §5.2 Redistribute compile into ONE executable on the shared mesh
    # (the in-program min-cut resharding replaces the host device_put)
    if two_grid_axis_split(p, q) is not None:
        jax.block_until_ready(nystrom_two_grid_fused(S, 5, r, p=p, q=q)[1])
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(
                nystrom_two_grid_fused(S, 5, r, p=p, q=q)[1])
        us = (time.perf_counter() - t0) / iters * 1e6
        fw = fused_redistribute_words(n, r, p, q)
        print(f"RESULT fig5-7_nystrom_bound_driven_fused_n{n}_r{r},"
              f"{us:.1f},p={p};q={q};redist_words_inprog={fw:.0f};"
              f"redist_words_cross={rw:.0f}")
"""


def main():
    out = run_with_devices(_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
