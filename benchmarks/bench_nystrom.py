"""Paper Figs. 5-8 — Redist vs No-Redist Nyström: runtime and communication
volume, including the P ~ n/r crossover of Fig. 7."""
from __future__ import annotations

from .common import run_with_devices

_SNIPPET = r"""
import time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core import nystrom_no_redist, nystrom_redist
from repro.roofline.hlo import collective_bytes_of

Pn = 8
mesh = Mesh(np.asarray(jax.devices()), ("x",))
for (n, r) in ((1024, 32), (512, 128)):   # n/r = 32 > P  and  n/r = 4 < P
    S = jax.random.normal(jax.random.key(2), (n, n))
    S = S @ S.T / n
    Ssh = jax.device_put(S, NamedSharding(mesh, P("x", None)))
    for name, fn in (("no_redist", nystrom_no_redist),
                     ("redist", nystrom_redist)):
        jfn = jax.jit(lambda a, f=fn: f(a, 5, r, mesh))
        jax.block_until_ready(jfn(Ssh))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(jfn(Ssh))
        us = (time.perf_counter() - t0) / 5 * 1e6
        cb = collective_bytes_of(jfn.lower(Ssh).compile().as_text()).total
        print(f"RESULT fig5-7_nystrom_{name}_n{n}_r{r},{us:.1f},"
              f"coll_bytes={cb:.0f};n_over_r={n//r};P={Pn}")
"""


def main():
    out = run_with_devices(_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
