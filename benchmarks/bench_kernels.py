"""Pallas fused sketch-matmul kernel (interpret mode) vs the jnp reference:
correctness at benchmark shapes + relative timing.  (Interpret mode executes
the kernel body in Python, so wall time is NOT a TPU estimate; the derived
column carries the HBM-traffic model that the fusion eliminates.)"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import sketch_matmul
from repro.kernels.ref import sketch_matmul_ref
from .common import emit, pick, time_us


def main():
    n1, n2, r = pick((512, 1024, 128), (64, 128, 32))
    bm, bn, bk = pick((128, 64, 256), (32, 16, 64))
    A = jax.random.normal(jax.random.key(0), (n1, n2), jnp.float32)

    ref = jax.jit(lambda a: sketch_matmul_ref(a, 9, r))
    ker = jax.jit(lambda a: sketch_matmul(a, seed=9, r=r, bm=bm, bn=bn,
                                          bk=bk, interpret=True))
    us_ref = time_us(ref, A)
    us_ker = time_us(ker, A, warmup=1, iters=2)
    err = float(jnp.abs(ker(A) - ref(A)).max())

    # HBM traffic model (bytes): GEMM moves A + Omega + B; fused moves A + B.
    b = 4
    gemm_bytes = (n1 * n2 + n2 * r + n1 * r) * b
    fused_bytes = (n1 * n2 + n1 * r) * b
    emit("kernel_sketch_matmul_ref", us_ref,
         f"hbm_bytes={gemm_bytes}")
    emit("kernel_sketch_matmul_fused_interp", us_ker,
         f"hbm_bytes={fused_bytes};saving={gemm_bytes/fused_bytes:.3f}x;"
         f"max_err={err:.1e}")


if __name__ == "__main__":
    main()
