"""Pallas fused sketch-matmul kernel (interpret mode) vs the jnp reference:
correctness at benchmark shapes + relative timing, plus the backend-matrix
rows behind the zero-Omega-HBM dispatch — per-backend HBM word counts on a
shape sweep with a bitwise-parity flag.  (Interpret mode executes the
kernel body on CPU, so wall time is NOT a TPU estimate; the derived column
carries the HBM-traffic model that the fusion eliminates, which is what the
planner dispatches on.)"""
from __future__ import annotations


import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import sketch_matmul
from repro.kernels.local import sketch_block
from repro.kernels.ref import sketch_matmul_ref
from repro.plan.model import hbm_roofline_words
from .common import emit, pick, time_us


def main():
    n1, n2, r = pick((512, 1024, 128), (64, 128, 32))
    bm, bn, bk = pick((128, 64, 256), (32, 16, 64))
    A = jax.random.normal(jax.random.key(0), (n1, n2), jnp.float32)

    ref = jax.jit(lambda a: sketch_matmul_ref(a, 9, r))
    ker = jax.jit(lambda a: sketch_matmul(a, seed=9, r=r, bm=bm, bn=bn,
                                          bk=bk, interpret=True))
    us_ref = time_us(ref, A)
    us_ker = time_us(ker, A, warmup=1, iters=2)
    err = float(jnp.abs(ker(A) - ref(A)).max())

    # HBM traffic model (bytes): GEMM moves A + Omega + B; fused moves A + B.
    b = 4
    gemm_bytes = (n1 * n2 + n2 * r + n1 * r) * b
    fused_bytes = (n1 * n2 + n1 * r) * b
    emit("kernel_sketch_matmul_ref", us_ref,
         f"hbm_bytes={gemm_bytes}")
    emit("kernel_sketch_matmul_fused_interp", us_ker,
         f"hbm_bytes={fused_bytes};saving={gemm_bytes/fused_bytes:.3f}x;"
         f"max_err={err:.1e}")

    # backend matrix: the local layer both distributed paths dispatch on.
    # Reports per-backend HBM words (the roofline the planner prices), the
    # fused reduction factor, and whether the backends agreed bit for bit
    # (the kernels/local.py contract — contraction un-split by default).
    shapes = pick(((256, 512, 64), (512, 1024, 128), (512, 2048, 64)),
                  ((32, 64, 16), (64, 128, 32)))
    for (m, k, n) in shapes:
        X = jax.random.normal(jax.random.key(1), (m, k), jnp.float32)
        jf = jax.jit(lambda a: sketch_block(a, 9, n, backend="jnp"))
        pf = jax.jit(lambda a: sketch_block(a, 9, n, backend="pallas"))
        us_j = time_us(jf, X, warmup=1, iters=pick(3, 2))
        us_p = time_us(pf, X, warmup=1, iters=pick(3, 2))
        bitwise = bool(np.array_equal(np.asarray(jf(X)), np.asarray(pf(X))))
        wj = hbm_roofline_words(m, k, n, "jnp")
        wp = hbm_roofline_words(m, k, n, "pallas")
        emit(f"kernel_backend_jnp_{m}x{k}x{n}", us_j,
             f"hbm_words={wj:.0f}")
        emit(f"kernel_backend_pallas_interp_{m}x{k}x{n}", us_p,
             f"hbm_words={wp:.0f};hbm_reduction={wj / wp:.3f}x;"
             f"bitwise_vs_jnp={int(bitwise)}")


if __name__ == "__main__":
    main()
