"""Planner benchmark: predicted vs. measured cost + autotune cache behavior.

Validates the planner's reason for existing on the running machine:
  * per shape, the analytic estimate next to the measured wall time;
  * across shapes, whether the predicted ordering matches the measured one
    (the planner only needs to *rank* correctly — see plan/model.py);
  * the autotune cache: first invocation measures and persists, the second
    is a pure hit;
  * a multi-device (8 fake devices) Alg.-1 grid sweep: the paper-optimal
    grid's predicted words vs. measured time against rival factorizations.
"""
from __future__ import annotations

import os
import tempfile

from .common import emit, pick, run_with_devices, time_us

SHAPES = [(256, 512, 32), (512, 512, 64), (1024, 256, 16), (2048, 1024, 64)]
SMOKE_SHAPES = [(64, 128, 16), (128, 128, 32), (256, 128, 16)]

_GRID_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp
from repro.plan import plan_sketch, PRESETS
from repro.core import rand_matmul, make_grid_mesh
from repro.core.sketch import input_sharding
from repro.plan.model import alg1_cost

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
n1, n2, r = (32, 256, 16) if smoke else (64, 1024, 32)
iters = 2 if smoke else 5
P = 8
plan = plan_sketch(n1, n2, r, P=P, machine=PRESETS["cpu"])
A = jax.random.normal(jax.random.key(0), (n1, n2))
grids = [plan.grid, (2, 2, 2), (1, 8, 1), (2, 4, 1)]
seen = []
for g in grids:
    if g in seen:
        continue
    seen.append(g)
    mesh = make_grid_mesh(*g)
    Ag = jax.device_put(A, input_sharding(mesh))
    fn = jax.jit(lambda a: rand_matmul(a, 7, r, mesh))
    jax.block_until_ready(fn(Ag))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(Ag))
    us = (time.perf_counter() - t0) / iters * 1e6
    words = alg1_cost(n1, n2, r, g).words
    tag = "chosen" if g == plan.grid else "rival"
    print(f"RESULT plan_grid_{g[0]}x{g[1]}x{g[2]},{us:.1f},"
          f"{tag};pred_words={words:.0f}")
"""


def main():
    import jax
    from repro.plan import AutotuneCache, autotune, plan_sketch

    # -- predicted vs measured, local dispatch, >= 3 shapes -----------------
    shapes = pick(SHAPES, SMOKE_SHAPES)
    rows = []
    for (n1, n2, r) in shapes:
        plan = plan_sketch(n1, n2, r, P=1)
        A = jax.random.normal(jax.random.key(0), (n1, n2))
        us = time_us(lambda: plan.execute(A, seed=1))
        emit(f"plan_sketch_{n1}x{n2}x{r}", us,
             f"variant={plan.variant};pred_us={plan.predicted_seconds*1e6:.1f}"
             f";pred_words={plan.predicted_words:.0f}"
             f";bound_words={plan.lower_bound_words:.0f}")
        rows.append((plan.predicted_seconds, us))
    pred_rank = sorted(range(len(rows)), key=lambda i: rows[i][0])
    meas_rank = sorted(range(len(rows)), key=lambda i: rows[i][1])
    emit("plan_pred_vs_measured_ordering", 0.0,
         f"agree={pred_rank == meas_rank};pred={pred_rank};meas={meas_rank}")

    # -- autotune: miss -> persist -> hit -----------------------------------
    path = os.path.join(tempfile.mkdtemp(prefix="repro_tune_"), "tune.json")
    plan = plan_sketch(*shapes[0], P=1)
    c1 = AutotuneCache(path)
    tuned = autotune(plan, cache=c1)
    c2 = AutotuneCache(path)
    tuned2 = autotune(plan, cache=c2)
    assert c1.misses == 1 and c2.hits == 1, (c1.misses, c2.hits)
    assert tuned2.variant == tuned.variant
    emit("plan_autotune_first", (tuned.measured_seconds or 0) * 1e6,
         f"variant={tuned.variant};cache_miss={c1.misses == 1}"
         f";persisted={os.path.exists(path)}")
    emit("plan_autotune_second", (tuned2.measured_seconds or 0) * 1e6,
         f"variant={tuned2.variant};cache_hit={c2.hits == 1}")

    # -- multi-device grid sweep (8 fake devices, subprocess) ---------------
    out = run_with_devices(_GRID_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
