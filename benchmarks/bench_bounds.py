"""Paper Theorems 2/3 — bound tables at the paper's experimental scales
(their §6 configurations), and the tightness of Alg. 1 (§4.3)."""
from __future__ import annotations

import time

from repro.core import (gemm_lower_bound, matmul_lower_bound,
                        nystrom_lower_bound, select_matmul_grid)
from .common import emit


def main():
    # metabarcoding: 1e6 x 1e6, r=1000 (their Fig. 4 data)
    for P in (256, 512, 1024, 4096):
        t0 = time.perf_counter()
        W = matmul_lower_bound(10**6, 10**6, 1000, P)
        g = select_matmul_grid(10**6, 10**6, 1000, P)
        us = (time.perf_counter() - t0) * 1e6
        emit(f"thm2_metabarcoding_P{P}", us,
             f"W_words={W:.3e};alg1_words={g.bandwidth_words:.3e};"
             f"grid={g.shape};gemm_words={gemm_lower_bound(10**6, 10**6, 1000, P):.3e}")

    # CIFAR kernel 50k x 50k, r in {500, 5000} (their Fig. 5-8 data)
    for r in (500, 5000):
        for P in (8, 32, 128, 512):
            t0 = time.perf_counter()
            W = nystrom_lower_bound(50000, r, P)
            us = (time.perf_counter() - t0) * 1e6
            emit(f"thm3_cifar_r{r}_P{P}", us, f"W_words={W:.3e}")


if __name__ == "__main__":
    main()
