"""Paper Fig. 3 — communicate Omega vs regenerate it redundantly.

Wall-clock of the two strategies for instantiating Omega on all P
processors (generation is step-indexed Philox, communication is the
all-gather variant of Alg. 1), plus the HLO collective-byte counts.
"""
from __future__ import annotations

from .common import run_with_devices

_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp
from repro.core import rand_matmul, rand_matmul_communicating, make_grid_mesh
from repro.core.sketch import input_sharding, omega_tile
from repro.roofline.hlo import collective_bytes_of

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
n1, n2 = (64, 128) if smoke else (512, 1024)
iters = 2 if smoke else 5
mesh = make_grid_mesh(2, 2, 2)
A = jax.device_put(jax.random.normal(jax.random.key(0), (n1, n2)),
                   input_sharding(mesh))
for r in ((16, 32) if smoke else (64, 256)):
    gen = jax.jit(lambda a: rand_matmul(a, 7, r, mesh))
    com = jax.jit(lambda a: rand_matmul_communicating(a, 7, r, mesh))
    for name, fn in (("generate", gen), ("communicate", com)):
        jax.block_until_ready(fn(A))
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(A))
        us = (time.perf_counter() - t0) / iters * 1e6
        cb = collective_bytes_of(fn.lower(A).compile().as_text()).total
        print(f"RESULT fig3_{name}_r{r},{us:.1f},collective_bytes={cb:.0f}")
"""


def main():
    out = run_with_devices(_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
