"""Paper Tab. 2 — Nyström approximation error on kernel matrices.

CIFAR-10 itself is not redistributable offline; we match its setup at
reduced scale: an (n x d) feature matrix -> linear kernel (known rank d)
and RBF kernels (sigma = ||X||/sqrt(n) vs sigma = 1), errors at several
sketch ranks.  Expected qualitative pattern (paper's): linear kernel ~
machine precision once r > d; well-scaled RBF decays fast; sigma=1 RBF
stays O(1).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import nystrom_reference, relative_error
from .common import emit, pick


def kernel_matrices(n=1024, d=96):
    X = jax.random.normal(jax.random.key(0), (n, d))
    lin = X @ X.T
    sq = jnp.sum(X * X, 1)
    d2 = sq[:, None] + sq[None, :] - 2 * X @ X.T
    sigma_good = float(jnp.linalg.norm(X)) / (n ** 0.5)
    rbf_good = jnp.exp(-d2 / (2 * sigma_good ** 2))
    rbf_bad = jnp.exp(-d2 / 2.0)
    return {"linear": lin, "rbf_scaled": rbf_good, "rbf_sigma1": rbf_bad}


def main():
    mats = kernel_matrices(n=pick(1024, 128), d=pick(96, 24))
    for kname, A in mats.items():
        for r in pick((32, 128, 256), (8, 16, 32)):
            t0 = time.perf_counter()
            B, C = nystrom_reference(A, 11, r)
            err = float(relative_error(A, B, C))
            us = (time.perf_counter() - t0) * 1e6
            emit(f"tab2_{kname}_r{r}", us, f"rel_err={err:.3e}")


if __name__ == "__main__":
    main()
