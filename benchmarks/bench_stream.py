"""Streaming-sketch benchmarks: update throughput + one-pass accuracy.

Columns:
  stream_rowblock_k{K}     — local row-block ingest at chunk height K;
                             derived: rows/s and whether the result is
                             bitwise-equal to the one-shot reference at
                             this chunk height (informational: tiny chunks
                             against a large contraction can drop to
                             reduction-order tolerance — see
                             docs/ARCHITECTURE.md invariant 2).
  stream_vs_oneshot        — full-matrix streamed in chunks vs a single
                             one-shot sketch call (amortized overhead).
  stream_recon_error       — one-pass reconstruction error vs the one-shot
                             low-rank baseline on a noisy low-rank matrix.
  stream_dist_update_P8    — distributed additive update on a (8,1,1) grid;
                             derived: per-device collective bytes (must be
                             zero — the regenerate-don't-communicate claim
                             carried over to streaming).
  stream_ragged_sustained_s64 — sustained multi-tenant ingest at 64
                             concurrent streams with ragged lane heights:
                             one shape-bucketed ``update_ragged`` round vs
                             64 serial ``update`` dispatches of the same
                             traffic; derived: streams/s, the dispatch
                             amortization ratio (must be >= 5x), p99
                             ingest latency through the async IngestQueue,
                             and whether ragged stayed bitwise-equal to
                             serial.
  stream_obs_overhead      — the same ``update_ragged`` round with the
                             repro.obs tracer + comm-ledger installed vs
                             uninstalled (interleaved min-of-pairs);
                             derived: the traced/untraced ratio — the
                             PR-7 budget is <= 1.02x (tests/test_obs.py
                             enforces it; this row trends it).
  stream_recovery_s{N}     — time-to-recover after the ingest worker is
                             killed mid-round at N tenants: WAL replay of
                             the journaled tail onto a fresh service
                             through the production recovery path
                             (bitwise verified — the kill-worker chaos
                             drill); derived: replayed records + words.
"""
from __future__ import annotations

import time

from .common import emit, pick, run_with_devices, time_us


def _local():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import sketch_reference
    from repro.stream import (StreamConfig, StreamingSketch, SketchService,
                              reconstruction_error)

    n1, n2, r = pick((2048, 1024, 64), (256, 128, 16))
    seed = 7
    A = jax.random.normal(jax.random.key(0), (n1, n2))

    # row-block ingest throughput at several chunk heights (service path:
    # one compiled executable per height, traced offsets)
    for k in pick((64, 256, 1024), (32, 64, 128)):
        svc = SketchService()
        cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=seed, corange=False)
        warm = svc.open(cfg)                # throwaway stream: compile only
        svc.update(warm, A[:k], row0=0)
        svc.close(warm)
        sid = svc.open(cfg)                 # shares the compiled update
        t0 = time.perf_counter()
        nup = 0
        for i in range(0, n1, k):
            svc.update(sid, A[i:i + k], row0=i)
            nup += 1
        jax.block_until_ready(svc.sketch(sid))
        dt = time.perf_counter() - t0
        rows_per_s = n1 / dt
        bitwise = np.array_equal(np.asarray(svc.sketch(sid)),
                                 np.asarray(sketch_reference(A, seed, r)))
        emit(f"stream_rowblock_k{k}", dt / nup * 1e6,
             f"rows_per_s={rows_per_s:.3g};bitwise={bitwise}")

    # streamed (16 chunks) vs one-shot: same result, amortized cost
    st = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=r, seed=seed,
                                      corange=False), backend="xla")
    k = n1 // 16

    def run_stream():
        st.Y = jnp.zeros_like(st.Y)
        for i in range(0, n1, k):
            st.update_rows(i, A[i:i + k])
        return st.Y

    us_stream = time_us(run_stream)
    us_oneshot = time_us(lambda: sketch_reference(A, seed, r))
    bitwise = np.array_equal(np.asarray(run_stream()),
                             np.asarray(sketch_reference(A, seed, r)))
    emit("stream_vs_oneshot", us_stream,
         f"oneshot_us={us_oneshot:.1f};bitwise={bitwise}")

    # one-pass reconstruction error on low-rank + noise
    rank = pick(16, 8)
    M = (jax.random.normal(jax.random.key(1), (n1, rank))
         @ jax.random.normal(jax.random.key(2), (rank, n2))
         + 1e-3 * jax.random.normal(jax.random.key(3), (n1, n2)))
    sr = StreamingSketch(StreamConfig(n1=n1, n2=n2, r=4 * rank, seed=5))
    step = pick(256, 64)
    for i in range(0, n1, step):
        sr.update_rows(i, M[i:i + step])
    t0 = time.perf_counter()
    err = float(reconstruction_error(M, sr.reconstruct(rank=rank)))
    us = (time.perf_counter() - t0) * 1e6
    emit("stream_recon_error", us, f"rel_err={err:.3e}")

    _ragged_sustained()
    _sparse_ingest()
    _obs_overhead()
    _stream_recovery()


def _sparse_ingest():
    """The PR-10 sparse-family rows: O(nnz) COO slab ingest vs densified
    row-block updates of the same traffic, and the planner's sparse-vs-
    dense verdict at the benchmarked density."""
    import numpy as np

    from repro.core.sketch import omega_tile, sketch_sparse_apply
    from repro.plan import plan_sketch
    from repro.stream import SparseRows, StreamConfig, StreamingSketch

    n1, n2, r = pick((2048, 1024, 8), (256, 128, 8))
    k = pick(256, 64)
    density = 0.01
    rng = np.random.default_rng(0)
    A = np.zeros((n1, n2), np.float32)
    nnz_total = int(density * n1 * n2)
    A.flat[rng.choice(n1 * n2, size=nnz_total, replace=False)] = \
        rng.standard_normal(nnz_total).astype(np.float32)

    for kind in ("countsketch", "rowsample"):
        cfg = StreamConfig(n1=n1, n2=n2, r=r, seed=7, kind=kind,
                           corange=False)
        slabs = [(i, SparseRows.from_dense(A[i:i + k]))
                 for i in range(0, n1, k)]

        def ingest():
            st = StreamingSketch(cfg, backend="xla")
            for row0, sp in slabs:
                st.update_rows_sparse(row0, sp)
            return st.Y

        def ingest_dense():
            st = StreamingSketch(cfg, backend="xla")
            for i in range(0, n1, k):
                st.update_rows(i, A[i:i + k])
            return st.Y

        us = time_us(ingest)
        us_dense = time_us(ingest_dense)
        close = bool(np.allclose(np.asarray(ingest()),
                                 np.asarray(ingest_dense()), atol=1e-4))
        emit(f"stream_sparse_ingest_{kind}", us / len(slabs),
             f"nnz_per_s={nnz_total / (us / 1e6):.3g};"
             f"dense_us_per_upd={us_dense / len(slabs):.1f};"
             f"match_dense_path={close}")

    # one-shot O(nnz) apply vs the materialized-Omega GEMM
    us_apply = time_us(lambda: sketch_sparse_apply(A, 7, r,
                                                   kind="countsketch"))
    us_gemm = time_us(lambda: A @ omega_tile(7, 0, 0, n2, r, "countsketch"))
    plan = plan_sketch(n1, n2, r, P=1, nnz=nnz_total)
    emit("sparse_apply_vs_gemm", us_apply,
         f"gemm_us={us_gemm:.1f};density={density};"
         f"planner_pick={plan.variant}")


def _ragged_sustained():
    """Sustained shape-bucketed ragged ingest at 64 concurrent streams vs
    64 serial dispatches of the same traffic (the PR-6 serving row)."""
    import jax
    import numpy as np

    from repro.plan import choose_bucket_edges
    from repro.stream import IngestQueue, SketchService, StreamConfig

    # (n2, r) stay fixed across modes: this row measures DISPATCH
    # amortization in the many-tenant thin-slab regime, and growing the
    # contraction just turns it compute-bound (per-lane Omega regen, paid
    # identically by both sides) — the rowblock/one-shot rows above cover
    # compute scaling.  Only the stream table height n1 scales.
    n1, n2, r = pick(1024, 256), 128, 8
    n_streams = 64
    # median of samples x rounds: each sample is long enough to reach
    # pipelined steady state, the median shrugs off host-load spikes
    samples, rounds = 4, 4
    rng = np.random.default_rng(0)
    cfg0 = dict(n1=n1, n2=n2, r=r, corange=False)
    cfgs = [StreamConfig(seed=s, **cfg0) for s in range(n_streams)]
    # fixed ragged traffic: mixed heights, per-lane offsets
    items = []
    for i in range(n_streams):
        k = int(2 ** rng.integers(0, 6))          # 1..32 rows
        items.append((i, rng.standard_normal((k, n2)).astype(np.float32),
                      int(rng.integers(0, n1 - k + 1))))
    edges = choose_bucket_edges([k for _, H, _ in items
                                 for k in (H.shape[0],)], n2, r,
                                corange=False)

    ragged = SketchService()
    serial = SketchService()
    rids = [ragged.open(c) for c in cfgs]
    sids = [serial.open(c) for c in cfgs]
    batch = [(rids[i], H, row0) for i, H, row0 in items]
    # warm: bucket programs, the per-lane read (gather) path, then
    # re-stack the cohort so the timed loop starts in steady state
    ragged.update_ragged(batch, bucket_edges=edges)
    jax.block_until_ready([ragged.sketch(s) for s in rids])
    ragged.update_ragged(batch, bucket_edges=edges)
    for _ in range(2):                            # compile + warm heights
        for i, H, row0 in items:
            serial.update(sids[i], H, row0=row0)
    ragged.sync()
    serial.sync()

    ts = []
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(rounds):
            ragged.update_ragged(batch, bucket_edges=edges)
        ragged.sync()
        ts.append((time.perf_counter() - t0) / rounds * 1e6)
    us_ragged = float(np.median(ts))

    ts = []
    for _ in range(samples):
        t0 = time.perf_counter()
        for _ in range(rounds):
            for i, H, row0 in items:
                serial.update(sids[i], H, row0=row0)
        serial.sync()
        ts.append((time.perf_counter() - t0) / rounds * 1e6)
    us_serial = float(np.median(ts))

    bitwise = all(
        np.array_equal(np.asarray(ragged.sketch(rids[i])),
                       np.asarray(serial.sketch(sids[i])))
        for i in range(n_streams))
    ratio = us_serial / us_ragged
    # p99 submit->applied latency through the bounded async queue; hold
    # the worker so one full window drains (a partial first drain would
    # compile fresh lane-count specializations and pollute the tail)
    with IngestQueue(ragged, depth=256, window=n_streams,
                     bucket_edges=edges) as q:
        q.hold()
        for i, H, row0 in items:
            q.submit(rids[i], H, row0)
        q.release()
        q.flush(raise_errors=True)
        p99_ms = q.stats()["latency_p99_s"] * 1e3
    emit("stream_ragged_sustained_s64", us_ragged,
         f"streams_per_s={n_streams / us_ragged * 1e6:.3g};"
         f"serial_us={us_serial:.1f};amortize={ratio:.1f}x;"
         f"p99_ms={p99_ms:.1f};bitwise={bitwise}")


def _obs_overhead():
    """Traced (tracer + comm-ledger installed) vs untraced ragged-update
    rounds, interleaved pairwise so both classes sample the same noise."""
    import numpy as np

    from repro import obs
    from repro.stream import SketchService, StreamConfig

    n1, n2, r = pick((1024, 512, 16), (256, 128, 8))
    n_streams, k = 16, pick(128, 64)
    svc = SketchService()
    sids = [svc.open(StreamConfig(n1=n1, n2=n2, r=r, seed=s, corange=False))
            for s in range(n_streams)]
    items = [(sid, np.ones((k, n2), np.float32), 0) for sid in sids]

    def one_round():
        svc.update_ragged(items)
        svc.sync()

    one_round()                             # compile + warm

    def timed():
        t0 = time.perf_counter()
        one_round()
        return time.perf_counter() - t0

    # reuse one tracer+ledger across pairs and warm the traced path once:
    # the row trends the steady-state cost, not the first-observe
    # site-registration cost a fresh ledger would re-bill every round
    tracer = obs.Tracer(max_spans=1_000_000)
    ledger = obs.CommLedger()
    obs.install_tracer(tracer)
    obs.install_ledger(ledger)
    one_round()
    obs.uninstall_observability()
    untraced = traced = float("inf")
    for _ in range(pick(40, 10)):
        untraced = min(untraced, timed())
        obs.install_tracer(tracer)
        obs.install_ledger(ledger)
        try:
            traced = min(traced, timed())
        finally:
            obs.uninstall_observability()
    emit("stream_obs_overhead", traced * 1e6,
         f"untraced_us={untraced * 1e6:.1f};"
         f"overhead={traced / untraced:.3f}x")


def _stream_recovery():
    """Time-to-recover after the worker is killed mid-round at 64 tenants:
    the kill-worker chaos drill (WAL replay onto a fresh service, bitwise
    verified) through the production recovery path."""
    from repro.stream import faults

    streams = pick(64, 8)
    n1, n2, r = pick((256, 128, 8), (64, 32, 4))
    out = faults.run_chaos_scenario("kill-worker", n1=n1, n2=n2, r=r,
                                    streams=streams, updates=3,
                                    verbose=False)
    assert out["recovered"], out
    emit(f"stream_recovery_s{streams}", out["recover_s"] * 1e6,
         f"replayed_records={out['replayed_records']};"
         f"replayed_words={out['replayed_words']};"
         f"bitwise={out['bitwise']}")


_DIST_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp
from repro.core import make_grid_mesh
from repro.core.sketch import input_sharding
from repro.roofline.hlo import collective_bytes_of
from repro.stream import StreamConfig, ShardedStreamingSketch

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
n, r = (256, 16) if smoke else (2048, 64)
iters = 2 if smoke else 5
mesh = make_grid_mesh(8, 1, 1)
cfg = StreamConfig(n1=n, n2=n, r=r, seed=7, corange=False)
st = ShardedStreamingSketch(cfg, mesh)
H = jax.device_put(jax.random.normal(jax.random.key(0), (n, n)),
                   input_sharding(mesh))
st.update(H)                                    # compile + warm
t0 = time.perf_counter()
for _ in range(iters):
    st.update(H)
jax.block_until_ready(st.sketch)
us = (time.perf_counter() - t0) / iters * 1e6
cb = collective_bytes_of(st._upd.lower(st.Y, st.W, H).compile().as_text())
print(f"RESULT stream_dist_update_P8,{us:.1f},coll_bytes={cb.total:.0f}")
assert cb.total == 0, cb
"""


def main():
    _local()
    out = run_with_devices(_DIST_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
