"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Multi-device benchmarks run in
subprocesses with 8 fake XLA devices so this process keeps 1 device.

    PYTHONPATH=src python -m benchmarks.run [--only fig3_comm_vs_gen,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (bench_bounds, bench_comm_vs_gen, bench_error,
               bench_grad_compress, bench_kernels, bench_nystrom,
               bench_plan, bench_sketch, bench_stream)

SUITES = {
    "thm_bounds": bench_bounds.main,        # Thm 2/3 tables
    "fig3_comm_vs_gen": bench_comm_vs_gen.main,
    "fig4_scaling": bench_sketch.main,
    "fig5-8_nystrom": bench_nystrom.main,
    "tab2_error": bench_error.main,
    "kernels": bench_kernels.main,
    "grad_compress": bench_grad_compress.main,
    "stream": bench_stream.main,
    "plan": bench_plan.main,                # predicted vs measured + autotune
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failed.append((name, e))
    if failed:
        print(f"# {len(failed)} suites FAILED: {[n for n, _ in failed]}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
