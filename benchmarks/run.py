"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Multi-device benchmarks run in
subprocesses with 8 fake XLA devices so this process keeps 1 device.

    PYTHONPATH=src python -m benchmarks.run [--only fig3_comm_vs_gen,...]
                                            [--smoke] [--out bench.json]

``--smoke`` sets REPRO_BENCH_SMOKE=1: every suite runs tiny shapes and
minimal iters (the CI bench-smoke job).  ``--out`` additionally writes the
parsed rows as JSON — the artifact CI uploads so the perf trajectory
(BENCH_*.json) is machine-produced, not hand-pasted.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shapes smoke mode (REPRO_BENCH_SMOKE=1)")
    ap.add_argument("--out", default=None,
                    help="write suite rows as JSON to this path")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    # import after --smoke is in the environment so suites (and their
    # subprocess snippets) all observe the same mode
    from . import (bench_bounds, bench_comm_vs_gen, bench_error,
                   bench_grad_compress, bench_kernels, bench_nystrom,
                   bench_plan, bench_sketch, bench_stream)

    suites = {
        "thm_bounds": bench_bounds.main,        # Thm 2/3 tables
        "fig3_comm_vs_gen": bench_comm_vs_gen.main,
        "fig4_scaling": bench_sketch.main,
        "fig5-8_nystrom": bench_nystrom.main,
        "tab2_error": bench_error.main,
        "kernels": bench_kernels.main,
        "grad_compress": bench_grad_compress.main,
        "stream": bench_stream.main,
        "plan": bench_plan.main,                # predicted vs measured + tune
    }

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    results = {}
    for name, fn in suites.items():
        if only and name not in only:
            continue
        buf = io.StringIO()
        err = None
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception as e:  # noqa: BLE001
            err = e
            failed.append((name, e))
        text = buf.getvalue()
        sys.stdout.write(text)
        if err is not None:
            traceback.print_exception(err)
        ok = err is None
        rows = []
        for line in text.splitlines():
            parts = line.split(",", 2)
            if len(parts) == 3:
                try:
                    us = float(parts[1])
                except ValueError:
                    continue
                rows.append({"name": parts[0], "us_per_call": us,
                             "derived": parts[2]})
        results[name] = {"ok": ok, "rows": rows}

    if args.out:
        with open(args.out, "w") as f:
            json.dump({"schema": 1, "smoke": args.smoke,
                       "suites": results}, f, indent=1)
        print(f"# wrote {args.out}", file=sys.stderr)

    if failed:
        print(f"# {len(failed)} suites FAILED: {[n for n, _ in failed]}",
              file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
