"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Multi-device benchmarks run in
subprocesses with 8 fake XLA devices so this process keeps 1 device.

    PYTHONPATH=src python -m benchmarks.run [--only fig3_comm_vs_gen,...]
                                            [--smoke] [--out bench.json]
                                            [--compare BASELINE.json ...]

``--smoke`` sets REPRO_BENCH_SMOKE=1: every suite runs tiny shapes and
minimal iters (the CI bench-smoke job).  ``--out`` additionally writes the
parsed rows as JSON — the artifact CI persists as ``BENCH_<PR>.json`` so
the perf trajectory is machine-produced, not hand-pasted; the committed
trend line lives in ``benchmarks/trends/``.

``--compare A.json [B.json]`` renders a trend table.  With two paths it is
a pure post-processing mode (no suites run): A is the baseline, B the
current run.  With one path the baseline is compared against the suites
just executed.  Wall-time ratios are informational (CI runners vary);
the comparison FAILS (exit 1) only on *coverage* regressions — a suite
that existed in the baseline but is now missing, failing, or empty — or
when ``--fail-ratio`` is given and a row slows past it.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import json
import os
import sys
import traceback


def load_results(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def compare(baseline: dict, current: dict,
            fail_ratio: float | None = None) -> int:
    """Print a per-row trend table; return a process exit code."""
    base_suites = baseline.get("suites", {})
    cur_suites = current.get("suites", {})
    failures = []
    print(f"# trend vs baseline (smoke={baseline.get('smoke')}"
          f" -> {current.get('smoke')})")
    print("suite,row,base_us,cur_us,ratio")
    for sname, bsuite in sorted(base_suites.items()):
        csuite = cur_suites.get(sname)
        if csuite is None:
            failures.append(f"suite {sname!r} disappeared")
            continue
        if bsuite.get("ok") and not csuite.get("ok"):
            failures.append(f"suite {sname!r} now failing")
        if bsuite.get("rows") and not csuite.get("rows"):
            failures.append(f"suite {sname!r} lost all rows")
        cur_rows = {r["name"]: r for r in csuite.get("rows", [])}
        for row in bsuite.get("rows", []):
            cur = cur_rows.get(row["name"])
            if cur is None:
                print(f"{sname},{row['name']},{row['us_per_call']:.1f},"
                      f"MISSING,-")
                continue
            ratio = (cur["us_per_call"] / row["us_per_call"]
                     if row["us_per_call"] else float("inf"))
            print(f"{sname},{row['name']},{row['us_per_call']:.1f},"
                  f"{cur['us_per_call']:.1f},{ratio:.2f}")
            # zero/degenerate baselines carry no trend signal: report the
            # inf ratio but never fail on it
            if (fail_ratio is not None and row["us_per_call"] > 0
                    and ratio > fail_ratio):
                failures.append(
                    f"{sname}/{row['name']} slowed {ratio:.2f}x "
                    f"(> {fail_ratio}x)")
    for sname in sorted(set(cur_suites) - set(base_suites)):
        for row in cur_suites[sname].get("rows", []):
            print(f"{sname},{row['name']},NEW,{row['us_per_call']:.1f},-")
    if failures:
        print(f"# trend compare FAILED: {failures}", file=sys.stderr)
        return 1
    print("# trend compare OK", file=sys.stderr)
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-shapes smoke mode (REPRO_BENCH_SMOKE=1)")
    ap.add_argument("--out", default=None,
                    help="write suite rows as JSON to this path")
    ap.add_argument("--compare", nargs="+", default=None, metavar="JSON",
                    help="baseline JSON (and optionally a current JSON for "
                         "pure post-processing) to trend-compare against")
    ap.add_argument("--fail-ratio", type=float, default=None,
                    help="fail when a row slows past this ratio "
                         "(default: wall times informational only)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="run the in-process suites under the repro.obs "
                         "tracer+ledger and write a Chrome trace_event "
                         "JSON to FILE (plus the honesty report to stderr)")
    args = ap.parse_args()
    if args.compare and len(args.compare) > 2:
        ap.error("--compare takes at most two JSON paths")
    if args.compare and len(args.compare) == 2:
        # pure post-processing: baseline vs an existing result file
        sys.exit(compare(load_results(args.compare[0]),
                         load_results(args.compare[1]),
                         args.fail_ratio))
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"

    # import after --smoke is in the environment so suites (and their
    # subprocess snippets) all observe the same mode
    from . import (bench_bounds, bench_comm_vs_gen, bench_error,
                   bench_grad_compress, bench_kernels, bench_nystrom,
                   bench_plan, bench_sketch, bench_stream)

    suites = {
        "thm_bounds": bench_bounds.main,        # Thm 2/3 tables
        "fig3_comm_vs_gen": bench_comm_vs_gen.main,
        "fig4_scaling": bench_sketch.main,
        "fig5-8_nystrom": bench_nystrom.main,
        "tab2_error": bench_error.main,
        "kernels": bench_kernels.main,
        "grad_compress": bench_grad_compress.main,
        "stream": bench_stream.main,
        "plan": bench_plan.main,                # predicted vs measured + tune
    }

    tracer = ledger = None
    if args.trace:
        # in-process suites only: subprocess benchmarks (fake multi-device
        # harnesses) run outside this tracer's process
        from repro import obs
        tracer, ledger, _ = obs.install_observability()

    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failed = []
    results = {}
    for name, fn in suites.items():
        if only and name not in only:
            continue
        buf = io.StringIO()
        err = None
        try:
            with contextlib.redirect_stdout(buf):
                fn()
        except Exception as e:  # noqa: BLE001
            err = e
            failed.append((name, e))
        text = buf.getvalue()
        sys.stdout.write(text)
        if err is not None:
            traceback.print_exception(err)
        ok = err is None
        rows = []
        for line in text.splitlines():
            parts = line.split(",", 2)
            if len(parts) == 3:
                try:
                    us = float(parts[1])
                except ValueError:
                    continue
                rows.append({"name": parts[0], "us_per_call": us,
                             "derived": parts[2]})
        results[name] = {"ok": ok, "rows": rows}

    if args.trace:
        from repro import obs
        tracer.export_chrome(args.trace)
        print(f"# trace written to {args.trace} ({len(tracer.spans)} spans)",
              file=sys.stderr)
        if len(ledger):
            print(obs.honesty_report(ledger), file=sys.stderr)
        obs.uninstall_observability()

    payload = {"schema": 1, "smoke": args.smoke, "suites": results}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.out}", file=sys.stderr)

    rc = 0
    if args.compare:
        rc = compare(load_results(args.compare[0]), payload,
                     args.fail_ratio)

    if failed:
        print(f"# {len(failed)} suites FAILED: {[n for n, _ in failed]}",
              file=sys.stderr)
        sys.exit(1)
    if rc:
        sys.exit(rc)


if __name__ == "__main__":
    main()
