"""Beyond-paper application — sketched gradient compression: collective
bytes of the compressed DP exchange vs exact pmean (the paper's
regenerate-don't-communicate trick applied to gradients)."""
from __future__ import annotations

from .common import run_with_devices

_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map
from repro.parallel.grad_compress import (compress_and_allreduce,
    init_error_fb, local_fb, stack_fb, comm_words_exact,
    comm_words_compressed)
from repro.roofline.hlo import collective_bytes_of

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
d1, d2 = (256, 512) if smoke else (2048, 8192)
rank, min_dim = (8, 64) if smoke else (32, 256)
mesh = Mesh(np.asarray(jax.devices()), ("data",))
shapes = {"wq": jnp.zeros((d1, d1)), "w_up": jnp.zeros((d1, d2))}
fb = init_error_fb(shapes, rank=rank, min_dim=min_dim, world=8)

def comp_step(g, fb):
    out, fb_l = compress_and_allreduce(g, local_fb(fb), step=jnp.int32(1),
                                       rank=rank, min_dim=min_dim,
                                       axis_name="data")
    return out, stack_fb(fb_l)

def exact_step(g):
    return jax.lax.pmean(g, "data")

cfn = jax.jit(shard_map(comp_step, mesh=mesh,
              in_specs=(P(), P("data")), out_specs=(P(), P("data")),
              check_vma=False))
efn = jax.jit(shard_map(exact_step, mesh=mesh, in_specs=P(),
              out_specs=P(), check_vma=False))

g = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), shapes)
for name, fn, args in (("compressed", cfn, (g, fb)), ("exact", efn, (g,))):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(fn(*args))
    us = (time.perf_counter() - t0) / 3 * 1e6
    cb = collective_bytes_of(fn.lower(*args).compile().as_text()).total
    print(f"RESULT grad_allreduce_{name},{us:.1f},coll_bytes={cb:.0f}")
we, wc = comm_words_exact(shapes), comm_words_compressed(shapes, rank,
                                                         min_dim)
print(f"RESULT grad_allreduce_model,0.0,exact_words={we};"
      f"compressed_words={wc};ratio={we/wc:.1f}x")
"""


def main():
    out = run_with_devices(_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
