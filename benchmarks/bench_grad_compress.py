"""Beyond-paper application — sketched gradient compression: collective
bytes of the compressed DP exchange vs exact pmean (the paper's
regenerate-don't-communicate trick applied to gradients), plus a
convergence-vs-wall-clock comparison: a gemma2_2b-class model trained on
8 DP workers through the planner-priced compressed step
(``train.make_dp_compressed_step``) vs the exact-pmean baseline — same
steps, loss reported side by side with per-step wall time and the words
each exchange puts on the wire (docs/TRAINING.md)."""
from __future__ import annotations

from .common import run_with_devices

_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core.compat import shard_map
from repro.parallel.grad_compress import (compress_and_allreduce,
    init_error_fb, local_fb, stack_fb, comm_words_exact,
    comm_words_compressed)
from repro.roofline.hlo import collective_bytes_of

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
d1, d2 = (256, 512) if smoke else (2048, 8192)
rank, min_dim = (8, 64) if smoke else (32, 256)
mesh = Mesh(np.asarray(jax.devices()), ("data",))
shapes = {"wq": jnp.zeros((d1, d1)), "w_up": jnp.zeros((d1, d2))}
fb = init_error_fb(shapes, rank=rank, min_dim=min_dim, world=8)

def comp_step(g, fb):
    out, fb_l = compress_and_allreduce(g, local_fb(fb), step=jnp.int32(1),
                                       rank=rank, min_dim=min_dim,
                                       axis_name="data")
    return out, stack_fb(fb_l)

def exact_step(g):
    return jax.lax.pmean(g, "data")

cfn = jax.jit(shard_map(comp_step, mesh=mesh,
              in_specs=(P(), P("data")), out_specs=(P(), P("data")),
              check_vma=False))
efn = jax.jit(shard_map(exact_step, mesh=mesh, in_specs=P(),
              out_specs=P(), check_vma=False))

g = jax.tree_util.tree_map(lambda x: jnp.ones_like(x), shapes)
for name, fn, args in (("compressed", cfn, (g, fb)), ("exact", efn, (g,))):
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(3):
        jax.block_until_ready(fn(*args))
    us = (time.perf_counter() - t0) / 3 * 1e6
    cb = collective_bytes_of(fn.lower(*args).compile().as_text()).total
    print(f"RESULT grad_allreduce_{name},{us:.1f},coll_bytes={cb:.0f}")
we, wc = comm_words_exact(shapes), comm_words_compressed(shapes, rank,
                                                         min_dim)
print(f"RESULT grad_allreduce_model,0.0,exact_words={we};"
      f"compressed_words={wc};ratio={we/wc:.1f}x")
"""


_TRAIN_SNIPPET = r"""
import os, time, jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.configs import get_config
from repro.configs.base import RunConfig
from repro.core.compat import shard_map
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import get_api
from repro.optim import adamw
from repro.optim.schedule import warmup_cosine
from repro.parallel.grad_compress import comm_words_exact
from repro.plan import plan_train_compression
from repro.train.state import TrainState
from repro.train.step import init_state, make_dp_compressed_step

smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
dims = dict(n_layers=2, d_model=32, d_ff=64, vocab=64, head_dim=8) \
    if smoke else dict(n_layers=2, d_model=64, d_ff=128, vocab=256,
                       head_dim=16)
steps, seq, rank = (8, 16, 4) if smoke else (40, 64, 8)
cfg = get_config("gemma2-2b").reduced(**dims)
api = get_api(cfg)
run = RunConfig(steps=steps, learning_rate=3e-3, warmup_steps=4,
                grad_compress_rank=rank, remat=False)
mesh = Mesh(np.asarray(jax.devices()), ("data",))
data = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=8)
shapes = jax.eval_shape(lambda k: api.init(k, cfg), jax.random.key(0))
plan = plan_train_compression(shapes, rank=rank, P=8)

def raw_step_fn():
    def body(state, batch):
        def loss_fn(p):
            return api.loss(p, cfg, batch, remat=False)
        loss, grads = jax.value_and_grad(loss_fn)(state.params)
        loss = jax.lax.pmean(loss, "data")
        grads = jax.lax.pmean(grads, "data")          # m*n words per matrix
        grads, gnorm = adamw.clip_by_global_norm(grads, run.grad_clip)
        lr = warmup_cosine(state.step, peak_lr=run.learning_rate,
                           warmup_steps=run.warmup_steps,
                           total_steps=run.steps)
        p, opt = adamw.update(grads, state.opt, state.params, lr,
                              weight_decay=run.weight_decay)
        return TrainState(p, opt, state.step + 1, state.error_fb), loss
    st = init_state(api, cfg, run, jax.random.key(0), world=8,
                    decisions=plan.decision_tree())
    sspec = jax.tree_util.tree_map(lambda _: P(), st)
    sspec = sspec.replace(error_fb=jax.tree_util.tree_map(
        lambda _: P("data"), st.error_fb))
    bspec = jax.tree_util.tree_map(lambda _: P("data"),
                                   next(Pipeline(data)))
    return st, jax.jit(shard_map(body, mesh=mesh,
                                 in_specs=(sspec, bspec),
                                 out_specs=(sspec, P()),
                                 check_vma=False))

def train(name, st, fn, words):
    pipe = Pipeline(data)
    st, l = fn(st, next(pipe))                        # compile
    jax.block_until_ready(l)
    losses, t0 = [], time.perf_counter()
    for _ in range(steps):
        st, l = fn(st, next(pipe))
        losses.append(l)
    jax.block_until_ready(losses[-1])
    us = (time.perf_counter() - t0) / steps * 1e6
    tail = float(np.mean([float(x if np.ndim(x) == 0 else
                                np.asarray(x).item()) for x in losses[-4:]]))
    print(f"RESULT grad_train_{name},{us:.1f},"
          f"loss={tail:.4f};steps={steps};exchange_words={words:.0f}")
    return tail

st0, raw_fn = raw_step_fn()
raw_loss = train("raw", st0, raw_fn, comm_words_exact(shapes))

comp = make_dp_compressed_step(api, cfg, run, mesh, plan=plan)
st0c = init_state(api, cfg, run, jax.random.key(0), world=8,
                  decisions=plan.decision_tree())
comp_fn = lambda st, b: (lambda o: (o[0], o[1]["loss"]))(comp(st, b))
comp_loss = train("compressed", st0c, comp_fn, plan.exchange_words)
ratio = comm_words_exact(shapes) / plan.exchange_words
print(f"RESULT grad_train_model,0.0,words_ratio={ratio:.1f}x;"
      f"loss_gap={comp_loss - raw_loss:+.4f}")
"""


def main():
    out = run_with_devices(_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])
    out = run_with_devices(_TRAIN_SNIPPET, ndev=8)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            print(line[len("RESULT "):])


if __name__ == "__main__":
    main()
