"""Render the roofline table (EXPERIMENTS.md §Roofline) from
dryrun_results.jsonl.  Keeps the LAST record per cell (later runs supersede).

    PYTHONPATH=src python -m benchmarks.rooflines [--jsonl FILE] [--md]
"""
from __future__ import annotations

import argparse
import json
from typing import Dict


def load_cells(path: str) -> Dict[str, dict]:
    cells: Dict[str, dict] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            r = json.loads(line)
            cells[r["cell"]] = r
    return cells


def fnum(x, nd=3):
    if x is None:
        return "-"
    if isinstance(x, str):
        return x
    if x == 0:
        return "0"
    return f"{x:.{nd}e}"


def render(cells: Dict[str, dict], md: bool = False, mesh: str = None):
    hdr = ["cell", "chips", "HLO_FLOPs", "HLO_bytes", "coll_bytes",
           "t_comp(s)", "t_mem(s)", "t_coll(s)", "bottleneck",
           "useful", "roofline_frac"]
    rows = []
    for cell in sorted(cells):
        r = cells[cell]
        if mesh and r.get("mesh") != mesh:
            continue
        if "skip" in r:
            rows.append([cell, "-", r["skip"], "", "", "", "", "", "", "",
                         ""])
            continue
        if "error" in r:
            rows.append([cell, "-", "ERROR " + r["error"][:40], "", "", "",
                         "", "", "", "", ""])
            continue
        rows.append([
            cell, str(r["chips"]), fnum(r["hlo_flops"]),
            fnum(r["hlo_bytes"]), fnum(r["collective_bytes"]),
            fnum(r["t_compute"]), fnum(r["t_memory"]),
            fnum(r["t_collective"]), r["bottleneck"],
            (f"{r['useful_ratio']:.3f}" if r.get("useful_ratio") else "-"),
            (f"{r['roofline_fraction']:.4f}"
             if r.get("roofline_fraction") is not None else "-"),
        ])
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "|".join("---" for _ in hdr) + "|")
        for row in rows:
            print("| " + " | ".join(row) + " |")
    else:
        w = [max(len(h), *(len(r[i]) for r in rows)) for i, h in
             enumerate(hdr)]
        print("  ".join(h.ljust(w[i]) for i, h in enumerate(hdr)))
        for row in rows:
            print("  ".join(c.ljust(w[i]) for i, c in enumerate(row)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jsonl", default="dryrun_results.jsonl")
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None,
                    choices=[None, "single_pod", "multi_pod"])
    args = ap.parse_args()
    render(load_cells(args.jsonl), md=args.md, mesh=args.mesh)


if __name__ == "__main__":
    main()
